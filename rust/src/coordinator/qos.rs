//! QoS precision router: multi-lane serving with per-class precision
//! plans, deadline-aware scheduling and online NSR telemetry.
//!
//! The paper's result is that BFP mantissa width trades accuracy for
//! hardware cost along a curve the NSR bound predicts — which makes
//! precision a *runtime resource*. This module turns that knob into a
//! serving fabric:
//!
//! * Every request carries a [`QosClass`] (`Gold`/`Standard`/`Economy`)
//!   and an absolute deadline (explicit, or the class default).
//! * The server runs one *lane* per class — a
//!   [`PreparedModel`] bound to that class's precision plan, all lanes
//!   built over **one** [`SharedWeightCache`] so a weight format used by
//!   two lanes is quantized once, not once per lane.
//! * A deadline-aware scheduler extends the dynamic batcher: per-class
//!   earliest-deadline-first queues, batches are **never** mixed across
//!   classes (the lanes run different plans), linger is anchored to the
//!   head request's enqueue time, and under queue pressure the
//!   admission/shed policy routes non-`Gold` traffic to the next-cheaper
//!   lane (recording the downgrade) instead of blowing `Gold` deadlines.
//! * Each lane carries an online [`NsrMonitor`]
//!   ([`crate::telemetry`]): sampled BFP-vs-f32 probe forwards stream
//!   into a Welford accumulator, and when the measured SNR falls below
//!   the plan's predicted §4 bound the lane hot-swaps to the next-safer
//!   step of its precision ladder through the existing schedule-swap
//!   path — without dropping a single in-flight request.
//!
//! # Execution: reference scheduler vs per-lane executors
//!
//! Two [`WorkerMode`]s execute the routed batches:
//!
//! * [`WorkerMode::Single`] — the reference scheduler: one thread owns
//!   every lane and runs scheduling, forwards, and telemetry probes
//!   serially. Simple and easy to reason about, but an economy batch
//!   (plus its f32 probe forward) blocks a gold deadline behind it. The
//!   bit-exactness suites pin against this mode.
//! * [`WorkerMode::PerLane`] — the scaling configuration: a *dispatcher*
//!   thread keeps ownership of the EDF queues and the linger/shed
//!   policy, and hands each class-pure batch over a bounded queue to a
//!   long-lived *executor* thread per lane. The dispatcher is never
//!   parked on one lane: the EDF pick prefers the most urgent class
//!   whose lane has queue room, and an offer that finds the lane still
//!   full after a short grace period bounces back into the EDF heaps —
//!   so a full economy queue cannot head-of-line-block a gold dispatch,
//!   and the shed policy keeps seeing the true backlog. Lanes execute
//!   concurrently, so gold never stalls behind cheaper work; the
//!   telemetry probe runs
//!   on the owning lane's executor *after* that batch's responses are
//!   out, and hot-swaps stay confined to that executor. Idle executors
//!   may *steal* eligible batches from the adjacent safer class (moving
//!   the work exactly one lane cheaper and recording a downgrade —
//!   never from `Gold`, and into the shed lane only when one is
//!   configured). Per-lane metrics are recorded into a local sink and
//!   folded into the shared [`Metrics`] once per batch
//!   ([`Metrics::merge_from`]), so no response ever takes the global
//!   mutex individually. Each executor budgets its nested GEMM/panel
//!   parallelism to `ambient_threads / lanes`
//!   ([`pool::share_threads`]), so concurrent lanes don't oversubscribe
//!   the machine.
//!
//! Routing, batch formation, and per-request logits are identical in
//! both modes (the integration suite asserts bit-exactness between
//! them); only concurrency and metric aggregation differ.
//!
//! # Resilience
//!
//! Executor panics are *events*, not the end of the server: every batch
//! executes under `catch_unwind`, a panicked batch is error-replied
//! with a typed [`QosError`] (`ExecutorPanic`), and the lane's
//! supervisor ([`SupervisedLane`]) rebuilds the executor over the same
//! shared weight cache under a bounded restart budget with exponential
//! backoff. A lane that exhausts its budget is *retired*: routing
//! permanently moves its traffic to the adjacent safer lane (never into
//! the shed lane), visible in [`QosServer::health`], [`Metrics`]
//! (`lane_restarts` / `lanes_retired`) and the final [`QosReport`]. A
//! deadline reaper ([`QosConfig::reap_grace`]) fails requests still
//! queued past `deadline + grace` with a typed `Timeout`, and
//! [`QosServer::begin_drain`] gives shutdown a bound: new work is
//! refused, queued work drains until the bound expires, and the rest is
//! failed `Draining`. Every accepted submit therefore resolves as
//! exactly one [`QosResult`] — a response or a typed error, never a
//! silently dropped channel. The deterministic fault-injection plane
//! ([`crate::runtime::faults`]) drives all of these paths in CI.

use super::batcher::BatchPolicy;
use super::metrics::Metrics;
use crate::autotune::PrecisionPlan;
use crate::models::Model;
use crate::nn::prepared::{PreparedModel, SharedWeightCache, WeightCache};
use crate::nn::Fp32Exec;
use crate::obs::{self, Clock};
use crate::quant::{BfpConfig, LayerSchedule};
use crate::runtime::faults::FaultInjector;
use crate::runtime::pool;
use crate::telemetry::{MonitorConfig, NsrMonitor, Verdict};
use crate::tensor::Tensor;
use std::collections::{BinaryHeap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A request's latency/quality class. `Gold` buys the safest precision
/// plan and the tightest deadline; `Economy` the cheapest plan and the
/// loosest deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QosClass {
    Gold,
    Standard,
    Economy,
}

impl QosClass {
    pub const ALL: [QosClass; 3] = [QosClass::Gold, QosClass::Standard, QosClass::Economy];

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Gold => "gold",
            QosClass::Standard => "standard",
            QosClass::Economy => "economy",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "gold" => Some(QosClass::Gold),
            "standard" => Some(QosClass::Standard),
            "economy" => Some(QosClass::Economy),
            _ => None,
        }
    }

    /// Deadline applied when a request does not carry its own.
    pub fn default_deadline(self) -> Duration {
        match self {
            QosClass::Gold => Duration::from_millis(25),
            QosClass::Standard => Duration::from_millis(100),
            QosClass::Economy => Duration::from_millis(400),
        }
    }

    /// Lane index: 0 = safest/most expensive, rising toward cheap.
    fn rank(self) -> usize {
        match self {
            QosClass::Gold => 0,
            QosClass::Standard => 1,
            QosClass::Economy => 2,
        }
    }
}

/// How routed batches execute: the single-thread reference scheduler, or
/// one dispatcher plus one executor thread per lane (see the module
/// docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerMode {
    /// One thread owns every lane: scheduling, forwards and telemetry
    /// probes run serially. The bit-exactness reference.
    Single,
    /// Dispatcher + one executor thread per lane over bounded queues.
    /// With `steal`, an idle executor takes eligible batches from the
    /// adjacent safer class (one lane cheaper, never gold, recorded as a
    /// downgrade).
    PerLane {
        steal: bool,
    },
}

impl WorkerMode {
    /// Parse a CLI/env spelling: `single`, `per-lane`,
    /// `per-lane-nosteal`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "single" => Some(WorkerMode::Single),
            "per-lane" | "perlane" => Some(WorkerMode::PerLane { steal: true }),
            "per-lane-nosteal" => Some(WorkerMode::PerLane { steal: false }),
            _ => None,
        }
    }

    /// Resolve from `BFP_QOS_WORKERS` (the CI matrix runs the QoS suite
    /// under both schedulers via this knob); unset or invalid values
    /// fall back to the single-worker reference.
    pub fn from_env() -> Self {
        match std::env::var("BFP_QOS_WORKERS") {
            Ok(v) => {
                let v = v.trim();
                Self::parse(v).unwrap_or_else(|| {
                    if !v.is_empty() {
                        eprintln!(
                            "BFP_QOS_WORKERS={v} not recognized (single|per-lane|per-lane-nosteal); using single"
                        );
                    }
                    WorkerMode::Single
                })
            }
            Err(_) => WorkerMode::Single,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WorkerMode::Single => "single",
            WorkerMode::PerLane { steal: true } => "per-lane",
            WorkerMode::PerLane { steal: false } => "per-lane-nosteal",
        }
    }
}

/// One rung of a lane's precision ladder: the schedule to execute plus
/// the predicted §4 SNR bound telemetry judges it against (NaN ⇒
/// unmonitored — e.g. the uniform paper-default fallback).
#[derive(Debug, Clone)]
pub struct LaneStep {
    pub schedule: LayerSchedule,
    pub predicted_snr_db: f64,
    pub label: String,
}

impl LaneStep {
    pub fn new(schedule: LayerSchedule, predicted_snr_db: f64, label: impl Into<String>) -> Self {
        Self { schedule, predicted_snr_db, label: label.into() }
    }

    /// A step executing an autotuned plan, bounded by its §4 prediction.
    pub fn from_plan(plan: &PrecisionPlan) -> Self {
        Self::new(
            plan.to_schedule(),
            plan.predicted_snr_db,
            format!("plan[{:.1}dB]", plan.predicted_snr_db),
        )
    }

    /// The ultimate fallback: the paper's uniform 8/8, unmonitored.
    pub fn uniform_paper() -> Self {
        Self::new(LayerSchedule::uniform(BfpConfig::paper_default()), f64::NAN, "uniform8/8")
    }

    /// An unmonitored uniform-width step (CLI `gold=9/9` syntax, tests).
    pub fn uniform(l_w: u32, l_i: u32) -> Self {
        let schedule = LayerSchedule::uniform(BfpConfig::new(l_w, l_i));
        Self::new(schedule, f64::NAN, format!("uniform{l_w}/{l_i}"))
    }
}

/// One lane's full precision ladder, operating point first, safer rungs
/// after — the hot-swap path walks toward the back.
#[derive(Debug, Clone)]
pub struct LaneSpec {
    pub ladder: Vec<LaneStep>,
}

impl LaneSpec {
    pub fn new(ladder: Vec<LaneStep>) -> Self {
        assert!(!ladder.is_empty(), "a lane needs at least one precision step");
        Self { ladder }
    }
}

/// The lane set of a QoS server: one lane per class plus an optional
/// *shed* lane below `Economy` that only downgraded traffic reaches.
#[derive(Debug, Clone)]
pub struct LaneSet {
    pub gold: LaneSpec,
    pub standard: LaneSpec,
    pub economy: LaneSpec,
    pub shed: Option<LaneSpec>,
}

impl LaneSet {
    /// Assemble the set from one operating step per lane. Ladders are
    /// derived automatically: each lane falls back through the safer
    /// classes' steps and terminates at the unmonitored uniform paper
    /// default (consecutive duplicate schedules collapse).
    pub fn from_steps(
        gold: LaneStep,
        standard: LaneStep,
        economy: LaneStep,
        shed: Option<LaneStep>,
    ) -> Self {
        fn ladder(own: &LaneStep, safer: &[&LaneStep]) -> Vec<LaneStep> {
            let mut steps = vec![own.clone()];
            for s in safer {
                if steps.last().is_none_or(|last| last.schedule != s.schedule) {
                    steps.push((*s).clone());
                }
            }
            let fallback = LaneStep::uniform_paper();
            if steps.last().is_none_or(|last| last.schedule != fallback.schedule) {
                steps.push(fallback);
            }
            steps
        }
        Self {
            gold: LaneSpec::new(ladder(&gold, &[])),
            standard: LaneSpec::new(ladder(&standard, &[&gold])),
            economy: LaneSpec::new(ladder(&economy, &[&standard, &gold])),
            shed: shed.map(|s| LaneSpec::new(ladder(&s, &[&economy, &standard, &gold]))),
        }
    }

    /// Build the set from autotuned plans, safest plan → `Gold`. With
    /// fewer plans than classes the cheapest plan is reused; a fourth
    /// plan becomes the shed lane.
    pub fn from_plans(plans: &[PrecisionPlan]) -> anyhow::Result<Self> {
        anyhow::ensure!(!plans.is_empty(), "lane set needs at least one precision plan");
        let mut sorted: Vec<&PrecisionPlan> = plans.iter().collect();
        sorted.sort_by(|a, b| b.predicted_snr_db.total_cmp(&a.predicted_snr_db));
        let step = |i: usize| LaneStep::from_plan(sorted[i.min(sorted.len() - 1)]);
        let shed = if sorted.len() > 3 { Some(step(3)) } else { None };
        Ok(Self::from_steps(step(0), step(1), step(2), shed))
    }
}

/// Outcome of one request through the QoS fabric.
#[derive(Debug, Clone)]
pub struct QosResponse {
    pub id: u64,
    pub logits: Tensor,
    /// The class the request asked for.
    pub class: QosClass,
    /// The lane that actually served it (differs from `class` on a
    /// downgrade).
    pub served_by: String,
    /// The active precision step of the serving lane.
    pub lane_plan: String,
    pub downgraded: bool,
    pub deadline_missed: bool,
    pub queue_wait: Duration,
    pub batch_size: usize,
    /// Monotone batch counter — responses sharing a `batch_seq` were
    /// served in the same batch (the class-purity invariant is asserted
    /// over this in the integration tests).
    pub batch_seq: u64,
}

/// Why a request failed with a typed error instead of a response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QosErrorKind {
    /// Failed by the deadline reaper: still queued past
    /// `deadline + reap_grace`, never served.
    Timeout,
    /// The serving lane's executor panicked with this request in
    /// flight; the supervisor respawns (or retires) the lane.
    ExecutorPanic,
    /// Every lane that could serve this request is retired (restart
    /// budgets exhausted).
    LaneRetired,
    /// The server is draining and the drain bound expired with this
    /// request still queued.
    Draining,
    /// The serving lane's forward produced non-finite logits (NaN/Inf)
    /// — data corruption caught by the output guard rail, not a crash.
    /// The batch is failed typed and the lane hot-swaps one rung safer
    /// through the same path an NSR violation takes; no respawn.
    CorruptOutput,
}

impl QosErrorKind {
    pub fn name(self) -> &'static str {
        match self {
            QosErrorKind::Timeout => "timeout",
            QosErrorKind::ExecutorPanic => "executor-panic",
            QosErrorKind::LaneRetired => "lane-retired",
            QosErrorKind::Draining => "draining",
            QosErrorKind::CorruptOutput => "corrupt-output",
        }
    }
}

/// A typed per-request failure. Every accepted submit resolves as
/// exactly one [`QosResult`]; this is the error arm.
#[derive(Debug, Clone)]
pub struct QosError {
    pub id: u64,
    pub class: QosClass,
    pub kind: QosErrorKind,
    pub message: String,
}

impl std::fmt::Display for QosError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} request {} failed ({}): {}",
            self.class.name(),
            self.id,
            self.kind.name(),
            self.message
        )
    }
}

impl std::error::Error for QosError {}

/// What a submitted request's receiver yields: the response, or a
/// typed failure.
pub type QosResult = Result<QosResponse, QosError>;

/// Admission/shed policy: when the total backlog exceeds
/// `queue_pressure`, non-`Gold` batches route one lane cheaper
/// (`Standard` → economy lane, `Economy` → shed lane when configured).
/// `Gold` is never downgraded.
#[derive(Debug, Clone, Copy)]
pub struct ShedPolicy {
    pub enabled: bool,
    /// Backlog (requests still queued at batch dispatch) above which
    /// downgrade kicks in.
    pub queue_pressure: usize,
}

impl Default for ShedPolicy {
    fn default() -> Self {
        Self { enabled: true, queue_pressure: 32 }
    }
}

/// QoS server configuration.
#[derive(Debug, Clone)]
pub struct QosConfig {
    pub policy: BatchPolicy,
    pub shed: ShedPolicy,
    pub monitor: MonitorConfig,
    pub workers: WorkerMode,
    /// Executor respawns each lane's supervisor may perform before the
    /// lane is retired for good.
    pub restart_budget: u32,
    /// Backoff before the first respawn; doubles per restart, capped at
    /// [`MAX_RESTART_BACKOFF`].
    pub restart_backoff: Duration,
    /// Arm the deadline reaper: requests still queued `grace` past
    /// their deadline are failed with a typed `Timeout` instead of
    /// occupying batches. `None` (the default) serves late requests and
    /// only flags `deadline_missed`, the pre-reaper behavior.
    pub reap_grace: Option<Duration>,
    /// Deterministic fault injection (chaos suite / CI); `None` — the
    /// default unless `BFP_FAULTS` is set — costs nothing.
    pub faults: Option<Arc<FaultInjector>>,
}

impl Default for QosConfig {
    fn default() -> Self {
        Self {
            policy: BatchPolicy::default(),
            shed: ShedPolicy::default(),
            monitor: MonitorConfig::default(),
            workers: WorkerMode::from_env(),
            restart_budget: 3,
            restart_backoff: Duration::from_millis(10),
            reap_grace: None,
            faults: FaultInjector::from_env(),
        }
    }
}

/// Pick the serving lane for a batch of `class` requests given the
/// backlog left in the queues. Returns `(lane index, downgraded)`.
fn route(class: QosClass, backlog: usize, shed: &ShedPolicy, lane_count: usize) -> (usize, bool) {
    let own = class.rank();
    if !shed.enabled || backlog <= shed.queue_pressure || class == QosClass::Gold {
        return (own, false);
    }
    let target = (own + 1).min(lane_count - 1);
    (target, target != own)
}

// ---- deadline-aware scheduling ---------------------------------------

struct QueuedRequest {
    id: u64,
    class: QosClass,
    image: Tensor,
    respond: Sender<QosResult>,
    enqueued_at: Instant,
    deadline: Instant,
    /// Submission order; tie-break for equal deadlines (FIFO).
    seq: u64,
}

/// Max-heap entry ordered so the earliest deadline pops first.
struct EdfEntry(QueuedRequest);

impl PartialEq for EdfEntry {
    fn eq(&self, other: &Self) -> bool {
        self.0.deadline == other.0.deadline && self.0.seq == other.0.seq
    }
}
impl Eq for EdfEntry {}
impl PartialOrd for EdfEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for EdfEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap pops the max, we want the earliest deadline
        other.0.deadline.cmp(&self.0.deadline).then(other.0.seq.cmp(&self.0.seq))
    }
}

/// Per-class earliest-deadline-first queues. Batches are popped from one
/// class only — the no-mixing invariant is structural.
#[derive(Default)]
struct EdfQueues {
    heaps: [BinaryHeap<EdfEntry>; 3],
}

impl EdfQueues {
    fn push(&mut self, r: QueuedRequest) {
        self.heaps[r.class.rank()].push(EdfEntry(r));
    }

    fn is_empty(&self) -> bool {
        self.heaps.iter().all(|h| h.is_empty())
    }

    fn total(&self) -> usize {
        self.heaps.iter().map(|h| h.len()).sum()
    }

    fn class_len(&self, c: QosClass) -> usize {
        self.heaps[c.rank()].len()
    }

    /// EDF across classes: the class whose head request is most urgent.
    fn pick_class(&self) -> Option<QosClass> {
        self.pick_class_where(|_| true)
    }

    /// [`EdfQueues::pick_class`] restricted to classes accepted by
    /// `eligible` — the per-lane dispatcher filters out classes whose
    /// target lane queue is backed up, so one slow lane never
    /// head-of-line-blocks dispatch for the others.
    fn pick_class_where(&self, eligible: impl Fn(QosClass) -> bool) -> Option<QosClass> {
        QosClass::ALL
            .iter()
            .copied()
            .filter(|&c| eligible(c))
            .filter_map(|c| self.heaps[c.rank()].peek().map(|e| (e.0.deadline, e.0.seq, c)))
            .min_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)))
            .map(|(_, _, c)| c)
    }

    fn head_enqueued(&self, c: QosClass) -> Option<Instant> {
        self.heaps[c.rank()].peek().map(|e| e.0.enqueued_at)
    }

    /// Pop up to `max` requests of one class in deadline order.
    fn pop_batch(&mut self, c: QosClass, max: usize) -> Vec<QueuedRequest> {
        let heap = &mut self.heaps[c.rank()];
        let mut batch = Vec::with_capacity(max.min(heap.len()));
        while batch.len() < max {
            match heap.pop() {
                Some(EdfEntry(r)) => batch.push(r),
                None => break,
            }
        }
        batch
    }

    /// The deadline reaper: fail every queued request whose deadline is
    /// more than `grace` past with a typed `Timeout`. Heap order is
    /// earliest-deadline-first, so popping while the head is expired
    /// reaps exactly the expired set of each class.
    fn reap(&mut self, now: Instant, grace: Duration, metrics: &Mutex<Metrics>) {
        for class in QosClass::ALL {
            let heap = &mut self.heaps[class.rank()];
            let mut reaped = 0u64;
            while let Some(head) = heap.peek() {
                if now <= head.0.deadline + grace {
                    break;
                }
                let Some(EdfEntry(r)) = heap.pop() else { break };
                obs::event_lane(obs::EventKind::Timeout, class.name());
                let _ = r.respond.send(Err(QosError {
                    id: r.id,
                    class,
                    kind: QosErrorKind::Timeout,
                    message: format!(
                        "request {} reaped: still queued {:?} past its deadline",
                        r.id, grace
                    ),
                }));
                reaped += 1;
            }
            if reaped > 0 {
                let mut m = metrics.lock().unwrap();
                for _ in 0..reaped {
                    m.record_timeout(class.name());
                }
            }
        }
    }

    /// Fail everything still queued (the drain bound expired) with a
    /// typed `Draining` error.
    fn fail_all(&mut self, metrics: &Mutex<Metrics>) {
        for class in QosClass::ALL {
            let heap = &mut self.heaps[class.rank()];
            if heap.is_empty() {
                continue;
            }
            let mut m = metrics.lock().unwrap();
            while let Some(EdfEntry(r)) = heap.pop() {
                m.record_failure(class.name());
                let _ = r.respond.send(Err(QosError {
                    id: r.id,
                    class,
                    kind: QosErrorKind::Draining,
                    message: "qos server drain bound expired".to_string(),
                }));
            }
        }
    }
}

// ---- lanes -----------------------------------------------------------

struct Lane {
    label: &'static str,
    prepared: PreparedModel,
    ladder: Vec<LaneStep>,
    pos: usize,
    monitor: NsrMonitor,
    swaps: u64,
    promotions: u64,
    batches: u64,
}

impl Lane {
    fn new(
        label: &'static str,
        model: Model,
        spec: &LaneSpec,
        cache: &SharedWeightCache,
        monitor: MonitorConfig,
    ) -> Self {
        let prepared =
            PreparedModel::with_cache(model, spec.ladder[0].schedule.clone(), Arc::clone(cache));
        prepared.warm();
        Self {
            label,
            prepared,
            ladder: spec.ladder.clone(),
            pos: 0,
            monitor: NsrMonitor::new(monitor),
            swaps: 0,
            promotions: 0,
            batches: 0,
        }
    }

    fn step(&self) -> &LaneStep {
        &self.ladder[self.pos]
    }

    /// Forward one class-pure batch. For a sampled batch the probe
    /// position (rotating across sampled batches —
    /// [`NsrMonitor::tick_batch_probe`]) and its input image are
    /// returned as the telemetry probe ticket; the probe itself
    /// ([`Lane::probe`]) runs *after* the batch's responses have been
    /// sent, so its extra f32 reference forward never sits on the
    /// response path.
    fn forward(&mut self, images: Vec<Tensor>) -> (Vec<Tensor>, Option<(usize, Tensor)>) {
        let probe_input =
            self.monitor.tick_batch_probe(images.len()).map(|idx| (idx, images[idx].clone()));
        let outputs = self.prepared.forward_batch(images);
        self.batches += 1;
        (outputs, probe_input)
    }

    /// Telemetry probe for a sampled batch: run the f32 reference forward
    /// for `img`, fold the NSR against the lane's already-computed BFP
    /// output into the monitor, and walk the ladder — one rung safer on a
    /// bound violation, one rung back toward the frontier after a
    /// sustained healthy window ([`NsrMonitor::promotion_ready`]).
    fn probe(&mut self, img: Tensor, bfp_output: &Tensor) {
        let reference = self.prepared.model().graph.execute(img, &mut Fp32Exec);
        self.monitor.record_probe(&reference.data, &bfp_output.data);
        if self.monitor.verdict(self.step().predicted_snr_db) == Verdict::Violation {
            self.swap_safer();
        } else if self.pos > 0
            && self.monitor.promotion_ready(self.ladder[self.pos - 1].predicted_snr_db)
        {
            self.swap_cheaper();
        }
    }

    /// Hot-swap to the next-safer ladder rung through the prepared
    /// model's schedule-swap path. In-flight batches are unaffected: the
    /// swap happens between batches on the lane's owning thread (the
    /// serving thread in single-worker mode, the lane's executor in
    /// per-lane mode), and queued requests simply execute under the
    /// safer schedule.
    fn swap_safer(&mut self) {
        if self.pos + 1 >= self.ladder.len() {
            return; // already at the safest rung
        }
        self.pos += 1;
        self.prepared.set_schedule(self.ladder[self.pos].schedule.clone());
        self.monitor.reset_probes();
        self.swaps += 1;
        obs::event_lane(obs::EventKind::Swap, self.label);
    }

    /// Fault-injection hook (`flip:weights:<lane>:<layer>:<nth>`): flip
    /// one mantissa bit of `layer`'s entry in the *shared* weight
    /// cache. The lane's own in-flight views share `Arc`s that stay
    /// clean — this models store-level corruption for the background
    /// scrubber to detect and repair, not corruption of data already
    /// handed to the execution engine.
    fn corrupt_cached_weights(&self, layer: &str) {
        let cache = self.prepared.shared_cache();
        if cache.lock().unwrap().corrupt_entry_bit(layer, 0) {
            obs::event_lane(obs::EventKind::Corrupt, self.label);
        }
    }

    /// The inverse of [`Lane::swap_safer`]: re-promote one rung back
    /// toward the lane's frontier operating point. Only reached after
    /// the monitor's sustained-healthy-window + hysteresis check
    /// ([`NsrMonitor::promotion_ready`] against the *target* rung's
    /// bound), through the same between-batches schedule-swap path on
    /// the lane's owning thread — in-flight batches are unaffected.
    fn swap_cheaper(&mut self) {
        debug_assert!(self.pos > 0, "already at the frontier rung");
        self.pos -= 1;
        self.prepared.set_schedule(self.ladder[self.pos].schedule.clone());
        self.monitor.reset_probes();
        self.promotions += 1;
        obs::event_lane(obs::EventKind::Promote, self.label);
    }

    fn report(&self) -> LaneReport {
        LaneReport {
            label: self.label.to_string(),
            plan: self.step().label.clone(),
            predicted_snr_db: self.step().predicted_snr_db,
            measured_snr_db: self.monitor.measured_snr_db(),
            probes: self.monitor.probes(),
            batches: self.batches,
            swaps: self.swaps,
            promotions: self.promotions,
            ladder_pos: self.pos,
            ladder_len: self.ladder.len(),
            restarts: 0,
            retired: false,
        }
    }
}

/// Telemetry snapshot of one lane at shutdown.
#[derive(Debug, Clone)]
pub struct LaneReport {
    pub label: String,
    /// The precision step the lane ended on.
    pub plan: String,
    pub predicted_snr_db: f64,
    /// Streaming measured SNR since the last hot-swap (+∞ = no probes).
    pub measured_snr_db: f64,
    pub probes: u64,
    pub batches: u64,
    /// Hot-swaps one rung safer (bound violations).
    pub swaps: u64,
    /// Walks one rung back toward the frontier (sustained health).
    pub promotions: u64,
    pub ladder_pos: usize,
    pub ladder_len: usize,
    /// Supervisor respawns of this lane's executor over its lifetime.
    pub restarts: u64,
    /// The lane exhausted its restart budget and serves nothing.
    pub retired: bool,
}

/// One lane's liveness as reported by [`QosServer::health`] and the
/// network `Health` frame.
#[derive(Debug, Clone)]
pub struct LaneHealth {
    pub label: String,
    /// Restart budget exhausted — the lane serves nothing; its traffic
    /// re-routes to the adjacent safer lane.
    pub retired: bool,
    /// Supervisor respawns of this lane's executor so far.
    pub restarts: u64,
    /// Requests currently queued for this lane's class in the EDF heaps
    /// (0 for the shed lane, which has no class queue of its own).
    pub queued: u64,
}

/// One lane's live counters as reported by [`QosServer::stats`], the
/// network `Stats` frame, and the `top` dashboard: the [`LaneHealth`]
/// liveness fields plus the lane's current ladder position and its
/// swap/promotion totals.
#[derive(Debug, Clone)]
pub struct LaneStats {
    pub label: String,
    pub retired: bool,
    pub restarts: u64,
    /// Requests queued for this lane's class (0 for the shed lane).
    pub queued: u64,
    /// Current precision-ladder rung, 1-based (1 = the frontier
    /// operating point; higher = safer fallbacks). 0 until the lane has
    /// published — callers treat that as "unknown".
    pub rung: u32,
    /// Total rungs in this lane's ladder.
    pub ladder: u32,
    /// Hot-swaps one rung safer (bound violations) over the lane's life.
    pub swaps: u64,
    /// Walks back toward the frontier (sustained health).
    pub promotions: u64,
}

/// Shared liveness/depth board: supervisors publish restarts and
/// retirements, the scheduler publishes class queue depths, and routing
/// plus [`QosServer::health`] read it lock-free.
struct HealthBoard {
    retired: Vec<AtomicBool>,
    restarts: Vec<AtomicU64>,
    /// Requests queued per class (gold/standard/economy) in the EDF
    /// heaps, as of the scheduler's last pass.
    depths: [AtomicUsize; 3],
    /// Ladder position per lane, packed `(pos + 1) << 8 | ladder_len`
    /// (0 = not yet published) — one word so a rung and its ladder
    /// length can never be read torn.
    rungs: Vec<AtomicU64>,
    /// Lifetime swap / promotion totals per lane, published by the
    /// owning executor after each batch.
    swaps: Vec<AtomicU64>,
    promotions: Vec<AtomicU64>,
    labels: Vec<&'static str>,
}

impl HealthBoard {
    fn new(labels: Vec<&'static str>) -> Self {
        Self {
            retired: labels.iter().map(|_| AtomicBool::new(false)).collect(),
            restarts: labels.iter().map(|_| AtomicU64::new(0)).collect(),
            depths: [AtomicUsize::new(0), AtomicUsize::new(0), AtomicUsize::new(0)],
            rungs: labels.iter().map(|_| AtomicU64::new(0)).collect(),
            swaps: labels.iter().map(|_| AtomicU64::new(0)).collect(),
            promotions: labels.iter().map(|_| AtomicU64::new(0)).collect(),
            labels,
        }
    }

    fn retire(&self, lane: usize) {
        // Release: the retiring executor's final metrics/queue writes
        // happen-before a router that Acquire-observes the retirement.
        self.retired[lane].store(true, Ordering::Release);
    }

    fn is_retired(&self, lane: usize) -> bool {
        // Acquire: pairs with `retire`'s Release (see there).
        self.retired[lane].load(Ordering::Acquire)
    }

    fn record_restart(&self, lane: usize) {
        // Relaxed: monotone stat counter, read only for reporting.
        self.restarts[lane].fetch_add(1, Ordering::Relaxed);
    }

    fn publish_depths(&self, queues: &EdfQueues) {
        for c in QosClass::ALL {
            // Relaxed: best-effort gauge for stats; staleness is fine.
            self.depths[c.rank()].store(queues.class_len(c), Ordering::Relaxed);
        }
    }

    /// Publish one lane's ladder position and swap/promotion totals (the
    /// owning executor calls this after each delivered batch, and the
    /// server once at startup so `stats` never reports rung 0 for a
    /// healthy lane).
    fn publish_lane(&self, lane: usize, pos: usize, len: usize, swaps: u64, promotions: u64) {
        let packed = ((pos as u64 + 1) << 8) | (len as u64).min(0xff);
        // Relaxed ×3: independent stats gauges; readers tolerate a torn
        // *set* (each word itself is atomic) — display only.
        self.rungs[lane].store(packed, Ordering::Relaxed);
        self.swaps[lane].store(swaps, Ordering::Relaxed);
        self.promotions[lane].store(promotions, Ordering::Relaxed);
    }

    fn snapshot(&self) -> Vec<LaneHealth> {
        self.labels
            .iter()
            .enumerate()
            .map(|(i, label)| LaneHealth {
                label: label.to_string(),
                retired: self.is_retired(i),
                restarts: self.restarts[i].load(Ordering::Relaxed), // Relaxed: stats gauge
                queued: if i < 3 { self.depths[i].load(Ordering::Relaxed) as u64 } else { 0 }, // Relaxed: gauge
            })
            .collect()
    }

    fn stats(&self) -> Vec<LaneStats> {
        self.labels
            .iter()
            .enumerate()
            .map(|(i, label)| {
                // Relaxed loads throughout: independent display gauges,
                // no cross-field consistency required.
                let packed = self.rungs[i].load(Ordering::Relaxed);
                LaneStats {
                    label: label.to_string(),
                    retired: self.is_retired(i),
                    restarts: self.restarts[i].load(Ordering::Relaxed), // Relaxed: gauge
                    queued: if i < 3 { self.depths[i].load(Ordering::Relaxed) as u64 } else { 0 }, // Relaxed: gauge
                    rung: (packed >> 8) as u32,
                    ladder: (packed & 0xff) as u32,
                    swaps: self.swaps[i].load(Ordering::Relaxed), // Relaxed: gauge
                    promotions: self.promotions[i].load(Ordering::Relaxed), // Relaxed: gauge
                }
            })
            .collect()
    }
}

/// Re-route a routed lane index around retired lanes. The adjacent
/// *safer* lane is preferred (serving better than asked is never a
/// downgrade), then cheaper lanes — but never *into* the shed lane,
/// which only the explicit pressure-downgrade path reaches. `None`
/// means every candidate is retired.
fn resolve_live(
    lane: usize,
    board: &HealthBoard,
    lane_count: usize,
    shed_lane: Option<usize>,
) -> Option<usize> {
    if !board.is_retired(lane) {
        return Some(lane);
    }
    for cand in (0..lane).rev() {
        if !board.is_retired(cand) {
            return Some(cand);
        }
    }
    let limit = shed_lane.unwrap_or(lane_count);
    ((lane + 1)..limit).find(|&cand| !board.is_retired(cand))
}

/// Graceful-drain state shared between [`QosServer`] and the scheduler:
/// `begin` flips admission off first, then arms the bound the scheduler
/// checks each pass.
#[derive(Default)]
struct DrainState {
    refusing: AtomicBool,
    deadline: Mutex<Option<Instant>>,
}

impl DrainState {
    fn begin(&self, bound: Duration) {
        // Release: admission readers that Acquire-see `refusing` also see
        // any state written before the drain began.
        self.refusing.store(true, Ordering::Release);
        let mut d = self.deadline.lock().unwrap();
        if d.is_none() {
            obs::event(obs::EventKind::Drain);
            *d = Some(Clock::now() + bound);
        }
    }

    fn refusing(&self) -> bool {
        // Acquire: pairs with the Release store in `begin`.
        self.refusing.load(Ordering::Acquire)
    }

    fn expired(&self) -> bool {
        matches!(*self.deadline.lock().unwrap(), Some(d) if Clock::now() >= d)
    }
}

/// Everything the QoS server knows at shutdown: per-class serving
/// metrics plus per-lane telemetry.
#[derive(Debug, Clone)]
pub struct QosReport {
    pub metrics: Metrics,
    pub lanes: Vec<LaneReport>,
    /// The serving thread (or the dispatcher) panicked before shutdown:
    /// `metrics` covers everything recorded up to the failure, and
    /// `lanes` holds whatever executors could still be joined — a
    /// partial report instead of a propagated panic.
    pub worker_panic: bool,
}

// ---- batch delivery (shared by both worker modes) --------------------

/// A routed, class-pure batch in flight from the scheduler to a lane.
struct LaneBatch {
    class: QosClass,
    batch_seq: u64,
    /// The dispatcher routed it one lane cheaper under pressure, or an
    /// idle executor stole it from the adjacent safer class.
    downgraded: bool,
    images: Vec<Tensor>,
    meta: Vec<ResponseMeta>,
}

/// Everything needed to answer one request after its forward.
struct ResponseMeta {
    id: u64,
    respond: Sender<QosResult>,
    enqueued_at: Instant,
    deadline: Instant,
}

fn split_requests(batch: Vec<QueuedRequest>) -> (Vec<Tensor>, Vec<ResponseMeta>) {
    let mut images = Vec::with_capacity(batch.len());
    let mut meta = Vec::with_capacity(batch.len());
    for r in batch {
        images.push(r.image);
        meta.push(ResponseMeta {
            id: r.id,
            respond: r.respond,
            enqueued_at: r.enqueued_at,
            deadline: r.deadline,
        });
    }
    (images, meta)
}

/// Human-readable text of a caught panic payload.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// A lane executor panicked. `meta` holds the poisoned batch's pending
/// responders (empty when the panic hit the post-response telemetry
/// probe — those responses were already out).
struct LaneFailure {
    class: QosClass,
    meta: Vec<ResponseMeta>,
    message: String,
}

/// Error-reply every pending responder with one typed [`QosError`],
/// accounting each under its class in `global` (`timeouts` for reaper
/// kills, `failures` otherwise).
fn fail_meta(
    meta: Vec<ResponseMeta>,
    class: QosClass,
    kind: QosErrorKind,
    message: &str,
    global: Option<&Mutex<Metrics>>,
) {
    if meta.is_empty() {
        return;
    }
    if let Some(g) = global {
        let mut m = g.lock().unwrap();
        for _ in &meta {
            match kind {
                QosErrorKind::Timeout => m.record_timeout(class.name()),
                _ => m.record_failure(class.name()),
            }
        }
    }
    for r in meta {
        let _ = r.respond.send(Err(QosError {
            id: r.id,
            class,
            kind,
            message: message.to_string(),
        }));
    }
}

/// [`fail_meta`] over a whole undelivered batch.
fn fail_batch(
    batch: LaneBatch,
    kind: QosErrorKind,
    message: &str,
    global: Option<&Mutex<Metrics>>,
) {
    fail_meta(batch.meta, batch.class, kind, message, global);
}

/// Execute one routed batch on `lane` and answer every request in it.
///
/// One completion instant is captured for the whole batch, immediately
/// after the forward: every response derives its latency *and* its
/// deadline-miss flag from that single clock read, so two requests
/// served in the same batch can never disagree on miss status because
/// later responses absorbed metrics or channel-send time (they used to:
/// `elapsed()`/`Instant::now()` were re-evaluated per response inside
/// the send loop). Metrics are recorded into the caller's `scratch` sink
/// and folded into `global` once per batch ([`Metrics::merge_from`]).
/// The sampled telemetry probe — and any hot-swap it triggers for the
/// *next* batch — runs last, after the responses are out, so its f32
/// reference forward never sits on the response path. Returns the
/// completion instant (the timing regression tests pin against it).
///
/// Between the forward and the replies sits the numeric guard rail: a
/// batch whose logits contain NaN/Inf is *corrupt output* — every
/// member is failed with a typed [`QosErrorKind::CorruptOutput`], the
/// `corrupt_outputs` counter bumps once per batch, and the lane swaps
/// one rung safer. The lane stays live (`Ok` is returned): corruption
/// is a data problem, not an executor crash.
///
/// The forward — and the fault injector's per-batch hook, which may
/// deliberately panic — runs under `catch_unwind`: a panic yields
/// `Err(LaneFailure)` carrying the poisoned batch's responders so the
/// supervisor can error-reply them and respawn the lane. A probe panic
/// yields a `LaneFailure` with no responders (the batch was already
/// answered) — the lane still needs a respawn, nobody needs a reply.
// LOCK-ORDER: `global` (the shared metrics mutex) is the only lock this
// function takes; each guard is a single-statement scope, never held
// across the other acquisition or any wait.
fn deliver_batch(
    lane: &mut Lane,
    batch: LaneBatch,
    scratch: &mut Metrics,
    global: &Mutex<Metrics>,
    faults: Option<&FaultInjector>,
) -> Result<Instant, LaneFailure> {
    let LaneBatch { class, batch_seq, downgraded, images, meta } = batch;
    let _lane_ctx = obs::armed().then(|| obs::lane_scope(lane.label));
    let t0 = Clock::now();
    // close each member's queue-wait span: enqueue → the instant its
    // batch started executing
    if obs::armed() {
        let t0_us = Clock::micros_of(t0);
        for m in &meta {
            let q0 = Clock::micros_of(m.enqueued_at);
            obs::record_span_at(obs::Stage::Queue, q0, t0_us.saturating_sub(q0));
        }
    }
    let batch_size = images.len();
    let label = lane.label;
    let fwd_span = obs::span(obs::Stage::Forward);
    let forwarded = catch_unwind(AssertUnwindSafe(|| {
        if let Some(f) = faults {
            if let Some(layer) = f.on_batch(label) {
                lane.corrupt_cached_weights(&layer);
            }
        }
        lane.forward(images)
    }));
    drop(fwd_span);
    let (outputs, probe) = match forwarded {
        Ok(v) => v,
        Err(payload) => {
            return Err(LaneFailure { class, meta, message: panic_message(payload) });
        }
    };
    // Numeric guard rail: a non-finite logit is data corruption, not a
    // crash. Fail the whole batch with a typed `CorruptOutput`, count
    // it, and move the lane one rung safer through the same
    // schedule-swap path an NSR violation takes — the lane stays live,
    // no respawn.
    if outputs.iter().any(|t| t.data.iter().any(|v| !v.is_finite())) {
        let completed = Clock::now();
        obs::event_lane(obs::EventKind::Corrupt, lane.label);
        global.lock().unwrap().record_corrupt_output();
        fail_meta(
            meta,
            class,
            QosErrorKind::CorruptOutput,
            &format!("lane {} produced non-finite logits", lane.label),
            Some(global),
        );
        lane.swap_safer();
        return Ok(completed);
    }
    // retained for the post-response telemetry probe (logits are small)
    let probe = probe.map(|(idx, img)| (img, outputs[idx].clone()));
    let served_by = lane.label.to_string();
    let lane_plan = lane.step().label.clone();
    let completed = Clock::now();
    let reply_span = obs::span(obs::Stage::Reply);
    for (m, logits) in meta.into_iter().zip(outputs) {
        let queue_wait = t0.duration_since(m.enqueued_at);
        let latency = completed.duration_since(m.enqueued_at);
        let deadline_missed = completed > m.deadline;
        scratch.record_class(
            class.name(),
            latency,
            queue_wait,
            batch_size,
            downgraded,
            deadline_missed,
        );
        let _ = m.respond.send(Ok(QosResponse {
            id: m.id,
            logits,
            class,
            served_by: served_by.clone(),
            lane_plan: lane_plan.clone(),
            downgraded,
            deadline_missed,
            queue_wait,
            batch_size,
            batch_seq,
        }));
    }
    drop(reply_span);
    global.lock().unwrap().merge_from(scratch);
    scratch.clear();
    if let Some((img, out)) = probe {
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| lane.probe(img, &out))) {
            return Err(LaneFailure { class, meta: Vec::new(), message: panic_message(payload) });
        }
    }
    Ok(completed)
}

// ---- lane supervision ------------------------------------------------

/// Cap on the supervisor's exponential restart backoff.
const MAX_RESTART_BACKOFF: Duration = Duration::from_secs(1);

/// Everything needed to rebuild a lane after an executor panic: the
/// supervisor respawns the [`Lane`] over the *same* shared weight cache
/// (no requantization) with a fresh telemetry monitor.
struct LaneSeed {
    label: &'static str,
    model: Model,
    spec: LaneSpec,
    cache: SharedWeightCache,
    monitor: MonitorConfig,
}

impl LaneSeed {
    fn build(&self) -> Lane {
        Lane::new(self.label, self.model.clone(), &self.spec, &self.cache, self.monitor)
    }
}

/// A lane under supervision: batches execute through [`deliver_batch`]'s
/// `catch_unwind`; a panic error-replies the poisoned batch and respawns
/// the lane within a bounded restart budget (exponential backoff,
/// capped at [`MAX_RESTART_BACKOFF`]). Exhausting the budget *retires*
/// the lane: it serves nothing further, the [`HealthBoard`] re-routes
/// its traffic, and the final report says so.
struct SupervisedLane {
    /// `None` once retired.
    lane: Option<Lane>,
    seed: LaneSeed,
    restarts: u64,
    budget: u32,
    next_backoff: Duration,
    /// Telemetry counters folded in from dead incarnations, so a
    /// respawned (or retired) lane's report covers its whole life.
    acc_batches: u64,
    acc_swaps: u64,
    acc_promotions: u64,
}

impl SupervisedLane {
    fn new(seed: LaneSeed, budget: u32, backoff: Duration) -> Self {
        let lane = seed.build();
        Self {
            lane: Some(lane),
            seed,
            restarts: 0,
            budget,
            next_backoff: backoff.max(Duration::from_micros(1)),
            acc_batches: 0,
            acc_swaps: 0,
            acc_promotions: 0,
        }
    }

    fn label(&self) -> &'static str {
        self.seed.label
    }

    fn retired(&self) -> bool {
        self.lane.is_none()
    }

    /// Run one batch; on an executor panic, error-reply the poisoned
    /// batch with a typed `ExecutorPanic` and respawn or retire per the
    /// restart budget.
    fn deliver(
        &mut self,
        batch: LaneBatch,
        scratch: &mut Metrics,
        global: &Mutex<Metrics>,
        faults: Option<&FaultInjector>,
        board: &HealthBoard,
        lane_idx: usize,
    ) {
        let Some(lane) = self.lane.as_mut() else {
            let msg = format!("lane {} is retired", self.seed.label);
            fail_batch(batch, QosErrorKind::LaneRetired, &msg, Some(global));
            return;
        };
        match deliver_batch(lane, batch, scratch, global, faults) {
            Ok(_) => {
                board.publish_lane(
                    lane_idx,
                    lane.pos,
                    lane.ladder.len(),
                    self.acc_swaps + lane.swaps,
                    self.acc_promotions + lane.promotions,
                );
            }
            Err(failure) => {
                scratch.clear();
                let msg =
                    format!("lane {} executor panicked: {}", self.seed.label, failure.message);
                fail_meta(
                    failure.meta,
                    failure.class,
                    QosErrorKind::ExecutorPanic,
                    &msg,
                    Some(global),
                );
                self.respawn_or_retire(global, board, lane_idx);
            }
        }
    }

    // LOCK-ORDER: only the shared metrics mutex is taken, in two disjoint
    // single-statement scopes — never nested, never held across the
    // backoff sleep.
    fn respawn_or_retire(&mut self, global: &Mutex<Metrics>, board: &HealthBoard, lane_idx: usize) {
        // fold the dead incarnation's telemetry counters before dropping it
        if let Some(old) = self.lane.take() {
            self.acc_batches += old.batches;
            self.acc_swaps += old.swaps;
            self.acc_promotions += old.promotions;
        }
        if self.restarts >= u64::from(self.budget) {
            obs::event_lane(obs::EventKind::Retire, self.seed.label);
            board.retire(lane_idx);
            global.lock().unwrap().record_retired();
            return; // lane stays None: retired for good
        }
        // Clock-aware: chaos/test runs can fast-forward the backoff by
        // advancing the mocked clock instead of waiting wall time.
        Clock::sleep(self.next_backoff);
        self.next_backoff = (self.next_backoff * 2).min(MAX_RESTART_BACKOFF);
        self.restarts += 1;
        obs::event_lane(obs::EventKind::Restart, self.seed.label);
        global.lock().unwrap().record_restart();
        board.record_restart(lane_idx);
        self.lane = Some(self.seed.build());
    }

    fn report(&self) -> LaneReport {
        match &self.lane {
            Some(lane) => {
                let mut r = lane.report();
                r.batches += self.acc_batches;
                r.swaps += self.acc_swaps;
                r.promotions += self.acc_promotions;
                r.restarts = self.restarts;
                r
            }
            None => LaneReport {
                label: self.seed.label.to_string(),
                plan: "retired".to_string(),
                predicted_snr_db: f64::NAN,
                measured_snr_db: f64::NAN,
                probes: 0,
                batches: self.acc_batches,
                swaps: self.acc_swaps,
                promotions: self.acc_promotions,
                ladder_pos: 0,
                ladder_len: self.seed.spec.ladder.len(),
                restarts: self.restarts,
                retired: true,
            },
        }
    }
}

// ---- the scheduler core ----------------------------------------------

/// Give a routed-but-undelivered batch back to the EDF heaps: its
/// target lane's queue stayed full for the whole dispatch grace period.
/// The requests keep their identity, deadlines and FIFO tie-break
/// (`seq == id` by construction in `submit_with_deadline`), and will be
/// re-batched — and re-routed, possibly to a cheaper lane if pressure
/// has risen meanwhile — on a later pass.
fn requeue(queues: &mut EdfQueues, batch: LaneBatch) {
    let LaneBatch { class, images, meta, .. } = batch;
    for (image, m) in images.into_iter().zip(meta) {
        queues.push(QueuedRequest {
            id: m.id,
            class,
            image,
            respond: m.respond,
            enqueued_at: m.enqueued_at,
            deadline: m.deadline,
            seq: m.id,
        });
    }
}

/// Shared fabric state threaded from [`QosServer::start`] into both
/// worker modes: the configuration plus the metrics sink, health board,
/// and drain state the resilience paths write.
struct FabricCtx {
    config: QosConfig,
    metrics: Arc<Mutex<Metrics>>,
    board: Arc<HealthBoard>,
    drain: Arc<DrainState>,
    /// Index of the shed lane, when configured — retirement re-routing
    /// must never move traffic into it.
    shed_lane: Option<usize>,
}

/// The EDF scheduling loop shared by the single-worker reference
/// scheduler and the per-lane dispatcher: drain the submission channel
/// into the per-class EDF heaps, linger anchored to the head request's
/// enqueue time, route each class-pure batch under the shed policy, and
/// hand `(lane index, batch)` to `dispatch` — which either executes it
/// inline (single) or offers it to the lane's executor (per-lane).
///
/// `lane_ready(lane)` reports whether a lane can accept a batch right
/// now; the EDF pick prefers the most urgent class whose routed lane is
/// ready, so one backed-up lane never head-of-line-blocks dispatch for
/// the other classes (a gold batch must not wait behind a full economy
/// queue). When *no* candidate's lane is ready, plain EDF order is used
/// and `dispatch` may return the batch undelivered — its requests go
/// back into the heaps (where the shed policy still sees them as
/// backlog) and the loop keeps draining the channel.
///
/// Resilience housekeeping runs once per pass: class queue depths are
/// published to the health board, the deadline reaper fails expired
/// requests (when armed), an expired drain bound fails everything still
/// queued, and routed lanes are re-resolved around retirements
/// ([`resolve_live`] — with every candidate retired the batch is failed
/// with a typed `LaneRetired`).
fn scheduler_loop(
    rx: &Receiver<QueuedRequest>,
    ctx: &FabricCtx,
    lane_count: usize,
    lane_ready: impl Fn(usize) -> bool,
    mut dispatch: impl FnMut(usize, LaneBatch) -> Option<LaneBatch>,
) {
    let config = &ctx.config;
    let mut queues = EdfQueues::default();
    let mut open = true;
    let mut batch_seq = 0u64;
    // route + retirement re-route: the lane a class's batch will target
    // right now, with the final downgrade flag derived from the lane it
    // actually lands on (a re-route to a *safer* lane is not a
    // downgrade). `None`: every candidate lane is retired.
    let target_lane = |class: QosClass, backlog: usize| -> Option<(usize, bool)> {
        let (routed, _) = route(class, backlog, &config.shed, lane_count);
        let live = resolve_live(routed, &ctx.board, lane_count, ctx.shed_lane)?;
        Some((live, live > class.rank()))
    };
    while open || !queues.is_empty() {
        if queues.is_empty() {
            match rx.recv() {
                Ok(r) => queues.push(r),
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        // drain everything already waiting in the channel
        while open {
            match rx.try_recv() {
                Ok(r) => queues.push(r),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => open = false,
            }
        }
        // resilience housekeeping before forming a batch
        if let Some(grace) = config.reap_grace {
            queues.reap(Clock::now(), grace, &ctx.metrics);
        }
        if ctx.drain.expired() {
            queues.fail_all(&ctx.metrics);
        }
        ctx.board.publish_depths(&queues);
        // most urgent class with a ready lane; with every candidate lane
        // backed up, fall back to plain EDF and let `dispatch` bounce
        let pick = |q: &EdfQueues| -> Option<QosClass> {
            q.pick_class_where(|c| {
                // readiness must validate the lane the dispatch below
                // will actually target: route with the backlog as it
                // will stand *after* popping this class's batch, or a
                // candidate straddling the pressure threshold gets
                // vetted against the downgrade lane and then offered to
                // its (full) home lane
                let popped = q.class_len(c).min(config.policy.max_batch);
                let backlog = q.total() - popped;
                match target_lane(c, backlog) {
                    Some((lane, _)) => lane_ready(lane),
                    // all-retired: still pick it, so the dispatch below
                    // can fail the batch instead of parking it forever
                    None => true,
                }
            })
            .or_else(|| q.pick_class())
        };
        let Some(mut class) = pick(&queues) else { continue };
        let assemble_start = obs::armed().then(Clock::micros);
        // linger anchored at the head request's enqueue time (not batch
        // start): a request that already waited its linger in the channel
        // closes the batch immediately
        if open && queues.class_len(class) < config.policy.max_batch {
            let anchor = match queues.head_enqueued(class) {
                Some(head) => head + config.policy.linger,
                // unreachable in practice: pick() just returned this class
                None => continue,
            };
            loop {
                if queues.class_len(class) >= config.policy.max_batch {
                    break;
                }
                let now = Clock::now();
                if now >= anchor {
                    break;
                }
                match rx.recv_timeout(anchor - now) {
                    Ok(r) => queues.push(r),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            // linger arrivals may be more urgent — EDF re-pick
            class = match pick(&queues) {
                Some(c) => c,
                // unreachable in practice: the picked head is still queued
                None => continue,
            };
        }
        let batch = queues.pop_batch(class, config.policy.max_batch);
        let backlog = queues.total();
        batch_seq += 1;
        let (images, meta) = split_requests(batch);
        if let Some(t0) = assemble_start {
            // linger + pop + split: the time spent forming this batch
            let _g = obs::lane_scope(class.name());
            obs::record_span_at(obs::Stage::Assemble, t0, Clock::micros().saturating_sub(t0));
        }
        match target_lane(class, backlog) {
            Some((lane_idx, downgraded)) => {
                if downgraded {
                    obs::event_lane(obs::EventKind::Shed, class.name());
                }
                let formed = LaneBatch { class, batch_seq, downgraded, images, meta };
                if let Some(bounced) = dispatch(lane_idx, formed) {
                    requeue(&mut queues, bounced);
                }
            }
            None => {
                let msg = "every lane that could serve this class is retired";
                fail_meta(meta, class, QosErrorKind::LaneRetired, msg, Some(&ctx.metrics));
            }
        }
    }
}

/// The single-worker reference scheduler: one thread owns every lane and
/// executes each routed batch inline.
fn run_worker(
    rx: Receiver<QueuedRequest>,
    mut lanes: Vec<SupervisedLane>,
    ctx: FabricCtx,
) -> Vec<LaneReport> {
    let lane_count = lanes.len();
    let mut scratch = Metrics::default();
    let faults = ctx.config.faults.clone();
    scheduler_loop(
        &rx,
        &ctx,
        lane_count,
        |_| true, // inline execution: every lane is always "ready"
        |lane_idx, batch| {
            let lane = &mut lanes[lane_idx];
            let faults = faults.as_deref();
            lane.deliver(batch, &mut scratch, &ctx.metrics, faults, &ctx.board, lane_idx);
            None
        },
    );
    lanes.iter().map(SupervisedLane::report).collect()
}

// ---- per-lane executors ----------------------------------------------

/// Batches a lane's bounded queue may hold before the dispatcher stops
/// offering it more. Small on purpose: backpressure keeps the backlog
/// in the EDF heaps, where the shed policy can still see (and
/// downgrade) it — the dispatcher skips backed-up lanes at the EDF pick
/// and bounces (requeues) a batch whose lane stays full past the grace
/// period, so it is never parked on one slow lane.
const LANE_QUEUE_CAP: usize = 4;

/// How long [`LaneQueues::offer`] waits for space before handing the
/// batch back to the dispatcher. Short: the dispatcher must get back to
/// draining the submission channel (a gold arrival must not sit behind
/// a full economy queue for longer than this).
const OFFER_GRACE: Duration = Duration::from_micros(500);

/// The bounded hand-off queues between the dispatcher and the per-lane
/// executors, with idle-steal across adjacent lanes.
struct LaneQueues {
    state: Mutex<QueueState>,
    /// Executors wait here for work (or close).
    work: Condvar,
    /// The dispatcher waits here for queue space.
    space: Condvar,
    /// Accounting sink for batches error-replied on a dead lane
    /// (`None` in the queue-mechanics unit tests).
    metrics: Option<Arc<Mutex<Metrics>>>,
}

struct QueueState {
    queues: Vec<VecDeque<LaneBatch>>,
    /// The dispatcher is done: no further pushes.
    closed: bool,
    /// `dead[i]`: lane `i`'s executor exited (drained after close, or
    /// panicked) — pushes to it are dropped instead of blocking forever.
    dead: Vec<bool>,
}

impl LaneQueues {
    fn new(lanes: usize, metrics: Option<Arc<Mutex<Metrics>>>) -> Self {
        Self {
            state: Mutex::new(QueueState {
                queues: (0..lanes).map(|_| VecDeque::new()).collect(),
                closed: false,
                dead: vec![false; lanes],
            }),
            work: Condvar::new(),
            space: Condvar::new(),
            metrics,
        }
    }

    /// Can `lane` accept a batch right now? (A dead lane reports ready:
    /// offering to it drops the batch immediately, which is how its
    /// clients learn of the failure — blocking would help nobody.)
    fn has_room(&self, lane: usize) -> bool {
        let st = self.state.lock().unwrap();
        st.dead[lane] || st.queues[lane].len() < LANE_QUEUE_CAP
    }

    /// Dispatcher: enqueue for `lane`, waiting up to [`OFFER_GRACE`] for
    /// space. Returns the batch if the queue stayed full — the caller
    /// requeues its requests and keeps scheduling other classes, so one
    /// slow lane never head-of-line-blocks the dispatcher. If the lane's
    /// executor has died, the batch is error-replied with a typed
    /// `LaneRetired` instead of queued — never a silent drop, never a
    /// blocked dispatcher.
    fn offer(&self, lane: usize, batch: LaneBatch) -> Option<LaneBatch> {
        let mut st = self.state.lock().unwrap();
        let deadline = Clock::now() + OFFER_GRACE;
        while st.queues[lane].len() >= LANE_QUEUE_CAP && !st.dead[lane] {
            let now = Clock::now();
            if now >= deadline {
                return Some(batch); // still full — bounce it back
            }
            st = self.space.wait_timeout(st, deadline - now).unwrap().0;
        }
        if st.dead[lane] {
            drop(st);
            let msg = "lane executor is gone";
            fail_batch(batch, QosErrorKind::LaneRetired, msg, self.metrics.as_deref());
            return None;
        }
        st.queues[lane].push_back(batch);
        drop(st);
        self.work.notify_all();
        None
    }

    /// Executor for `lane`: pop its own queue; when idle and `steal` is
    /// on, take one batch from the adjacent *safer* lane instead —
    /// moving the work exactly one lane cheaper, which is the same edge
    /// the pressure-downgrade path uses. Only batches still sitting on
    /// their home lane are eligible (`!downgraded`, class matches the
    /// source lane), so stolen work is never downgraded twice; gold
    /// (lane 0) has no thief, and the shed lane exists only when
    /// configured. Returns `None` once the dispatcher has closed and
    /// nothing eligible remains; the bool is `true` for a stolen batch.
    fn pop(&self, lane: usize, steal: bool) -> Option<(LaneBatch, bool)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(b) = st.queues[lane].pop_front() {
                drop(st);
                self.space.notify_all();
                return Some((b, false));
            }
            if steal && lane >= 2 {
                let src = lane - 1;
                let eligible = st.queues[src]
                    .iter()
                    .position(|b| !b.downgraded && b.class.rank() == src);
                if let Some(b) = eligible.and_then(|i| st.queues[src].remove(i)) {
                    drop(st);
                    self.space.notify_all();
                    return Some((b, true));
                }
            }
            if st.closed {
                return None;
            }
            st = self.work.wait(st).unwrap();
        }
    }

    /// Dispatcher is done: wake idle executors so they drain and exit.
    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.work.notify_all();
    }

    /// Lane `lane`'s executor is gone (drained after close, or retired).
    /// Batches still queued for it are error-replied with a typed
    /// `LaneRetired` — waiting clients get an answer, not a hang — and
    /// the dispatcher is woken so a push to the dead lane cannot block
    /// forever.
    fn mark_dead(&self, lane: usize) {
        let mut st = self.state.lock().unwrap();
        st.dead[lane] = true;
        let orphans: Vec<LaneBatch> = st.queues[lane].drain(..).collect();
        drop(st);
        for b in orphans {
            let msg = "lane executor exited with this batch still queued";
            fail_batch(b, QosErrorKind::LaneRetired, msg, self.metrics.as_deref());
        }
        self.space.notify_all();
        self.work.notify_all();
    }
}

/// Everything a per-lane executor thread needs besides its lane.
struct ExecEnv {
    queues: Arc<LaneQueues>,
    steal: bool,
    thread_budget: usize,
    metrics: Arc<Mutex<Metrics>>,
    faults: Option<Arc<FaultInjector>>,
    board: Arc<HealthBoard>,
}

/// One lane's long-lived executor: pop (or steal) batches, execute and
/// answer them through the lane's supervisor (panics are caught,
/// error-replied and respawned inside [`SupervisedLane::deliver`]), run
/// the post-response telemetry probe, fold local metrics into the
/// shared sink once per batch. Nested GEMM/panel parallelism is
/// budgeted to `ambient / lanes` threads so concurrent executors don't
/// oversubscribe the machine. A *retired* lane's executor exits: its
/// queue is marked dead (queued batches error-replied) and the
/// dispatcher re-routes around it via the health board.
fn run_executor(mut lane: SupervisedLane, lane_idx: usize, env: ExecEnv) -> LaneReport {
    // mark the lane dead on ANY exit — drained or retired — so the
    // dispatcher never blocks pushing to a queue nobody will empty
    struct DeadOnExit {
        queues: Arc<LaneQueues>,
        lane: usize,
    }
    impl Drop for DeadOnExit {
        fn drop(&mut self) {
            self.queues.mark_dead(self.lane);
        }
    }
    let _guard = DeadOnExit { queues: Arc::clone(&env.queues), lane: lane_idx };
    pool::with_threads(env.thread_budget, || {
        let mut scratch = Metrics::default();
        while let Some((mut batch, stolen)) = env.queues.pop(lane_idx, env.steal) {
            if stolen {
                batch.downgraded = true;
                obs::event_lane(obs::EventKind::Steal, lane.label());
            }
            let faults = env.faults.as_deref();
            lane.deliver(batch, &mut scratch, &env.metrics, faults, &env.board, lane_idx);
            if lane.retired() {
                break;
            }
        }
    });
    lane.report()
}

/// The per-lane dispatcher: spawn one executor per lane, run the shared
/// EDF scheduling loop handing batches over the bounded queues, then
/// close the queues and join the executors. Executor panics are caught
/// *inside* the executors (lane supervision), so every lane — retired
/// ones included — contributes its `LaneReport`.
fn run_dispatcher(
    rx: Receiver<QueuedRequest>,
    lanes: Vec<SupervisedLane>,
    ctx: FabricCtx,
) -> Vec<LaneReport> {
    // a steal serves requests on a cheaper plan — it is a downgrade, and
    // obeys the same master switch as the pressure-downgrade path: an
    // operator who disabled shedding gets strictly class-homed serving
    let steal = matches!(ctx.config.workers, WorkerMode::PerLane { steal: true })
        && ctx.config.shed.enabled;
    let lane_count = lanes.len();
    let queues = Arc::new(LaneQueues::new(lane_count, Some(Arc::clone(&ctx.metrics))));
    let thread_budget = pool::share_threads(lane_count);
    let executors: Vec<JoinHandle<LaneReport>> = lanes
        .into_iter()
        .enumerate()
        .map(|(i, lane)| {
            let env = ExecEnv {
                queues: Arc::clone(&queues),
                steal,
                thread_budget,
                metrics: Arc::clone(&ctx.metrics),
                faults: ctx.config.faults.clone(),
                board: Arc::clone(&ctx.board),
            };
            std::thread::Builder::new()
                .name(format!("qos-lane-{}", lane.label()))
                .spawn(move || run_executor(lane, i, env))
                // LINT-ALLOW: serving-unwrap — OS thread spawn failing at
                // server startup is unrecoverable; no request is in flight.
                .expect("spawn lane executor")
        })
        .collect();
    scheduler_loop(
        &rx,
        &ctx,
        lane_count,
        |lane| queues.has_room(lane),
        |lane_idx, batch| queues.offer(lane_idx, batch),
    );
    queues.close();
    executors.into_iter().filter_map(|h| h.join().ok()).collect()
}

// ---- the server ------------------------------------------------------

/// Background integrity-scrub cadence. Short enough that the chaos
/// suite's "corruption detected within one scrub period" SLO resolves
/// quickly; generation parking keeps the idle-cache cost to one lock +
/// one load per period regardless.
pub const SCRUB_PERIOD: Duration = Duration::from_millis(25);

/// Spawn the background integrity scrubber: a low-priority thread that
/// walks the shared weight cache verifying every entry's checksum
/// ([`WeightCache::scrub`]) and requantizing corrupted entries from the
/// still-resident fp32 weights. The thread *parks* while the cache
/// generation is unchanged since its last pass — the clean steady state
/// pays one mutex lock and one integer compare per period, never a
/// checksum walk. Each completed pass records
/// [`Metrics::record_scrub`]; repairs additionally emit a `corrupt`
/// instant event per healed layer.
// LOCK-ORDER: cache before metrics; the cache guard is dropped before
// the metrics lock is taken, so the two are never held together.
fn spawn_scrubber(
    model: Model,
    cache: SharedWeightCache,
    metrics: Arc<Mutex<Metrics>>,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        // sentinel: the first tick always verifies, so entries quantized
        // during lane warmup get one startup pass before parking
        let mut seen_gen = u64::MAX;
        // Relaxed: shutdown flag; one stale read costs one extra period.
        while !stop.load(Ordering::Relaxed) {
            // Clock-aware: tests fast-forward the scrub cadence by
            // advancing the mocked clock instead of sleeping for real.
            Clock::sleep(SCRUB_PERIOD);
            if cache.lock().unwrap().generation() == seen_gen {
                continue; // parked: cache unchanged since the last pass
            }
            let report = {
                let mut c = cache.lock().unwrap();
                let r = c.scrub(&model);
                // re-read: a repair pass bumps the generation itself
                seen_gen = c.generation();
                r
            };
            metrics.lock().unwrap().record_scrub(report.repaired.len() as u64);
            obs::event(obs::EventKind::Scrub);
            for layer in &report.repaired {
                obs::event_lane(obs::EventKind::Corrupt, layer);
            }
        }
    })
}

/// Handle to a running QoS precision router.
pub struct QosServer {
    tx: Option<Sender<QueuedRequest>>,
    worker: Option<JoinHandle<Vec<LaneReport>>>,
    metrics: Arc<Mutex<Metrics>>,
    board: Arc<HealthBoard>,
    drain: Arc<DrainState>,
    next_id: u64,
    started: Instant,
    /// Tells the integrity scrubber to exit at its next tick.
    scrub_stop: Arc<AtomicBool>,
    scrubber: Option<JoinHandle<()>>,
}

impl QosServer {
    /// Build every lane over one shared weight cache and spawn the
    /// serving fabric per `config.workers`: the single scheduler/worker
    /// thread, or the dispatcher plus one executor thread per lane.
    pub fn start(model: Model, set: &LaneSet, config: QosConfig) -> Self {
        let cache = WeightCache::shared();
        let monitor = config.monitor;
        let budget = config.restart_budget;
        let backoff = config.restart_backoff;
        let seed = |label: &'static str, spec: &LaneSpec| LaneSeed {
            label,
            model: model.clone(),
            spec: spec.clone(),
            cache: Arc::clone(&cache),
            monitor,
        };
        let mut lanes = vec![
            SupervisedLane::new(seed("gold", &set.gold), budget, backoff),
            SupervisedLane::new(seed("standard", &set.standard), budget, backoff),
            SupervisedLane::new(seed("economy", &set.economy), budget, backoff),
        ];
        if let Some(shed) = &set.shed {
            lanes.push(SupervisedLane::new(seed("shed", shed), budget, backoff));
        }
        let shed_lane = set.shed.as_ref().map(|_| 3);
        let labels: Vec<&'static str> = lanes.iter().map(|l| l.label()).collect();
        let board = Arc::new(HealthBoard::new(labels));
        // seed the stats board so a lane that has served nothing yet
        // still reports its frontier rung and ladder depth
        for (i, lane) in lanes.iter().enumerate() {
            board.publish_lane(i, 0, lane.seed.spec.ladder.len(), 0, 0);
        }
        let drain = Arc::new(DrainState::default());

        let (tx, rx): (Sender<QueuedRequest>, Receiver<QueuedRequest>) = channel();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let scrub_stop = Arc::new(AtomicBool::new(false));
        let scrubber = spawn_scrubber(
            model,
            Arc::clone(&cache),
            Arc::clone(&metrics),
            Arc::clone(&scrub_stop),
        );
        let workers = config.workers;
        let ctx = FabricCtx {
            config,
            metrics: Arc::clone(&metrics),
            board: Arc::clone(&board),
            drain: Arc::clone(&drain),
            shed_lane,
        };
        let worker = match workers {
            WorkerMode::Single => std::thread::spawn(move || run_worker(rx, lanes, ctx)),
            WorkerMode::PerLane { .. } => {
                std::thread::spawn(move || run_dispatcher(rx, lanes, ctx))
            }
        };
        Self {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            board,
            drain,
            next_id: 0,
            started: Clock::now(),
            scrub_stop,
            scrubber: Some(scrubber),
        }
    }

    /// Submit one image under `class` with the class-default deadline.
    /// Errors when the serving fabric is gone (stopped, or its worker
    /// panicked) instead of panicking the client.
    pub fn submit(
        &mut self,
        class: QosClass,
        image: Tensor,
    ) -> anyhow::Result<Receiver<QosResult>> {
        let deadline = class.default_deadline();
        self.submit_with_deadline(class, image, deadline)
    }

    /// Submit with an explicit per-request deadline (relative to now).
    pub fn submit_with_deadline(
        &mut self,
        class: QosClass,
        image: Tensor,
        deadline: Duration,
    ) -> anyhow::Result<Receiver<QosResult>> {
        let (tx, rx) = channel();
        let id = self.reserve_id();
        self.submit_reserved(id, class, image, deadline, tx)?;
        Ok(rx)
    }

    /// Reserve the next internal request id without enqueuing anything.
    /// Callers that index their own bookkeeping by the id *before* the
    /// response can possibly arrive (the TCP front's out-of-order writer
    /// thread) reserve first, record the id, then enqueue with
    /// [`QosServer::submit_reserved`] — enqueuing before recording would
    /// race the response past the bookkeeping.
    pub fn reserve_id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    /// Enqueue a request under a previously reserved id, answering on a
    /// caller-provided channel. One channel may serve many requests (a
    /// connection fans every response into a single writer thread);
    /// responses carry the id so the caller can correlate.
    pub fn submit_reserved(
        &mut self,
        id: u64,
        class: QosClass,
        image: Tensor,
        deadline: Duration,
        respond: Sender<QosResult>,
    ) -> anyhow::Result<()> {
        if self.drain.refusing() {
            anyhow::bail!("qos server is draining; {} request {id} refused", class.name());
        }
        let now = Clock::now();
        let worker = self
            .tx
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("qos server already shut down"))?;
        worker
            .send(QueuedRequest {
                id,
                class,
                image,
                respond,
                enqueued_at: now,
                deadline: now + deadline,
                seq: id,
            })
            .map_err(|_| {
                anyhow::anyhow!(
                    "qos worker is gone (panicked or exited); {} request {id} rejected",
                    class.name()
                )
            })?;
        Ok(())
    }

    /// The shared metrics sink. The TCP front records per-tenant quota
    /// accounting into the same `Metrics` the serving fabric writes, so
    /// one report covers both.
    pub fn metrics_handle(&self) -> Arc<Mutex<Metrics>> {
        Arc::clone(&self.metrics)
    }

    /// Submit and wait (tests / simple clients). A typed per-request
    /// failure (timeout, executor panic, retired lane, drain) — or a
    /// worker that dies mid-request — surfaces as an error, not a
    /// client-side panic.
    pub fn infer(&mut self, class: QosClass, image: Tensor) -> anyhow::Result<QosResponse> {
        match self.submit(class, image)?.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(anyhow::anyhow!("{e}")),
            Err(_) => Err(anyhow::anyhow!(
                "qos worker dropped the response (serving fabric died mid-request)"
            )),
        }
    }

    /// Per-lane liveness snapshot: retired flags, restart counts, and
    /// the class queue depths as of the scheduler's last pass. This is
    /// what the network `Health` frame reports.
    pub fn health(&self) -> Vec<LaneHealth> {
        self.board.snapshot()
    }

    /// Per-lane live counters for the network `Stats` frame and the
    /// `top` dashboard: [`LaneHealth`] plus each lane's current
    /// precision-ladder rung and its swap/promotion totals.
    pub fn stats(&self) -> Vec<LaneStats> {
        self.board.stats()
    }

    /// Start a graceful drain: new submits are refused immediately, and
    /// requests still queued when `bound` expires are failed with a
    /// typed `Draining` error. Already-dispatched batches always finish.
    pub fn begin_drain(&self, bound: Duration) {
        self.drain.begin(bound);
    }

    /// [`QosServer::begin_drain`] followed by [`QosServer::shutdown`]:
    /// the graceful stop the TCP front's drain path uses. Every pending
    /// request resolves — served within the bound, or failed typed.
    pub fn shutdown_with_drain(self, bound: Duration) -> QosReport {
        self.begin_drain(bound);
        self.shutdown()
    }

    /// Snapshot of the metrics so far (the wall time keeps running).
    pub fn metrics(&self) -> Metrics {
        let mut m = self.metrics.lock().unwrap().clone();
        m.wall_time = self.started.elapsed();
        m
    }

    /// Drain the queues, stop the workers, and return the final report.
    /// A panicked worker yields a *partial* report (`worker_panic` set,
    /// metrics up to the failure, whatever lane reports survive) instead
    /// of propagating the panic into the caller.
    pub fn shutdown(mut self) -> QosReport {
        drop(self.tx.take());
        // flag first so the scrubber winds down while the worker drains
        self.scrub_stop.store(true, Ordering::Relaxed);
        let (lanes, worker_panic) = match self.worker.take() {
            Some(w) => match w.join() {
                Ok(lanes) => (lanes, false),
                Err(_) => (Vec::new(), true),
            },
            None => (Vec::new(), false),
        };
        if let Some(s) = self.scrubber.take() {
            let _ = s.join();
        }
        let mut metrics = self.metrics.lock().unwrap().clone();
        metrics.wall_time = self.started.elapsed();
        QosReport { metrics, lanes, worker_panic }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Block;

    fn tiny_model(seed: u64) -> Model {
        let mut rng = crate::data::Rng::new(seed);
        Model {
            name: "tiny".into(),
            graph: Block::seq(vec![
                Block::Conv(crate::models::init::conv2d("c1", 4, 2, 3, 3, 1, 1, &mut rng)),
                Block::ReLU,
                Block::Conv(crate::models::init::conv2d("c2", 3, 4, 3, 3, 1, 1, &mut rng)),
                Block::Flatten,
            ]),
            input_shape: vec![2, 8, 8],
            num_classes: 0,
        }
    }

    fn image(seed: u64) -> Tensor {
        let mut rng = crate::data::Rng::new(seed);
        Tensor::from_vec(rng.normal_vec(2 * 8 * 8, 1.2), &[2, 8, 8])
    }

    fn queued(class: QosClass, seq: u64, deadline_ms: u64) -> QueuedRequest {
        let now = Instant::now();
        QueuedRequest {
            id: seq,
            class,
            image: Tensor::zeros(&[1, 1, 1]),
            respond: channel().0,
            enqueued_at: now,
            deadline: now + Duration::from_millis(deadline_ms),
            seq,
        }
    }

    /// An empty routed batch shell for the queue/steal unit tests.
    fn lane_batch(class: QosClass, batch_seq: u64, downgraded: bool) -> LaneBatch {
        LaneBatch { class, batch_seq, downgraded, images: Vec::new(), meta: Vec::new() }
    }

    #[test]
    fn edf_orders_within_class() {
        let mut q = EdfQueues::default();
        q.push(queued(QosClass::Gold, 1, 50));
        q.push(queued(QosClass::Gold, 2, 10));
        q.push(queued(QosClass::Gold, 3, 30));
        let batch = q.pop_batch(QosClass::Gold, 8);
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3, 1], "not earliest-deadline-first");
    }

    #[test]
    fn edf_picks_most_urgent_class() {
        let mut q = EdfQueues::default();
        q.push(queued(QosClass::Gold, 1, 100));
        q.push(queued(QosClass::Economy, 2, 5));
        assert_eq!(q.pick_class(), Some(QosClass::Economy));
        q.push(queued(QosClass::Gold, 3, 1));
        assert_eq!(q.pick_class(), Some(QosClass::Gold));
    }

    #[test]
    fn equal_deadlines_fall_back_to_fifo() {
        let mut q = EdfQueues::default();
        let base = Instant::now() + Duration::from_millis(50);
        for seq in 1..=3 {
            let mut r = queued(QosClass::Standard, seq, 0);
            r.deadline = base;
            q.push(r);
        }
        let ids: Vec<u64> = q.pop_batch(QosClass::Standard, 8).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn pop_batch_never_mixes_classes_and_respects_max() {
        let mut q = EdfQueues::default();
        for seq in 0..6 {
            q.push(queued(QosClass::Gold, seq, 10 + seq));
            q.push(queued(QosClass::Economy, 100 + seq, 10 + seq));
        }
        let batch = q.pop_batch(QosClass::Gold, 4);
        assert_eq!(batch.len(), 4, "max_batch cutoff");
        assert!(batch.iter().all(|r| r.class == QosClass::Gold), "classes mixed in a batch");
        assert_eq!(q.class_len(QosClass::Gold), 2);
        assert_eq!(q.class_len(QosClass::Economy), 6);
    }

    #[test]
    fn route_downgrades_only_under_pressure_and_never_gold() {
        let shed = ShedPolicy { enabled: true, queue_pressure: 4 };
        // no pressure: everyone stays home
        for c in QosClass::ALL {
            assert_eq!(route(c, 4, &shed, 4), (c.rank(), false));
        }
        // pressure: gold stays, standard → economy lane, economy → shed lane
        assert_eq!(route(QosClass::Gold, 100, &shed, 4), (0, false));
        assert_eq!(route(QosClass::Standard, 100, &shed, 4), (2, true));
        assert_eq!(route(QosClass::Economy, 100, &shed, 4), (3, true));
        // without a shed lane economy has nowhere cheaper to go
        assert_eq!(route(QosClass::Economy, 100, &shed, 3), (2, false));
        // disabled policy never downgrades
        let off = ShedPolicy { enabled: false, queue_pressure: 0 };
        assert_eq!(route(QosClass::Standard, 100, &off, 4), (1, false));
    }

    #[test]
    fn worker_mode_parses_and_names_round_trip() {
        for mode in [
            WorkerMode::Single,
            WorkerMode::PerLane { steal: true },
            WorkerMode::PerLane { steal: false },
        ] {
            assert_eq!(WorkerMode::parse(mode.name()), Some(mode));
        }
        assert_eq!(WorkerMode::parse("perlane"), Some(WorkerMode::PerLane { steal: true }));
        assert_eq!(WorkerMode::parse("threads"), None);
    }

    /// Accepted offers return `None`; the tests below rely on it.
    fn push_ok(q: &LaneQueues, lane: usize, batch: LaneBatch) {
        assert!(q.offer(lane, batch).is_none(), "offer to lane {lane} unexpectedly bounced");
    }

    /// Steal eligibility: only from the adjacent safer lane, only
    /// batches still on their home lane, never from gold.
    #[test]
    fn lane_queues_steal_moves_work_one_lane_cheaper_and_never_gold() {
        let q = LaneQueues::new(4, None);
        push_ok(&q, 0, lane_batch(QosClass::Gold, 1, false));
        push_ok(&q, 1, lane_batch(QosClass::Standard, 2, false));
        // a pressure-downgraded standard batch sitting on the economy
        // lane: not stealable (it would be downgraded twice)
        push_ok(&q, 2, lane_batch(QosClass::Standard, 3, true));

        // the standard executor (lane 1) must NOT steal gold's batch:
        // its own queue has work anyway, and after draining it the only
        // candidate source would be lane 0, which stealing never touches
        let (own, stolen) = q.pop(1, true).expect("own batch");
        assert_eq!((own.batch_seq, stolen), (2, false));

        // economy's executor (lane 2) pops its own (downgraded) batch
        // first — and once lane 1 is empty there is nothing to steal
        let (own, stolen) = q.pop(2, true).expect("own batch");
        assert_eq!((own.batch_seq, stolen), (3, false));

        // a fresh standard batch on its home lane IS stealable by the
        // economy executor, and arrives flagged as stolen
        push_ok(&q, 1, lane_batch(QosClass::Standard, 4, false));
        let (sb, stolen) = q.pop(2, true).expect("stolen batch");
        assert_eq!((sb.batch_seq, sb.class, stolen), (4, QosClass::Standard, true));

        // the shed executor (lane 3) steals economy's home-lane batches
        push_ok(&q, 2, lane_batch(QosClass::Economy, 5, false));
        let (b, stolen) = q.pop(3, true).expect("stolen economy batch");
        assert_eq!((b.batch_seq, stolen), (5, true));

        // gold's batch is still exactly where it was left
        let (g, stolen) = q.pop(0, true).expect("gold batch untouched");
        assert_eq!((g.batch_seq, stolen), (1, false));

        // with stealing off, an idle executor sees nothing after close
        push_ok(&q, 1, lane_batch(QosClass::Standard, 6, false));
        q.close();
        assert!(q.pop(2, false).is_none(), "nosteal executor must drain only its own lane");
        let (b, _) = q.pop(1, false).expect("home lane still drains after close");
        assert_eq!(b.batch_seq, 6);
        assert!(q.pop(1, false).is_none(), "closed and empty");
    }

    /// A full lane reports no room and bounces the offer back after the
    /// grace period instead of parking the dispatcher on it; draining
    /// one batch reopens the lane.
    #[test]
    fn full_lane_bounces_offers_instead_of_blocking() {
        let q = LaneQueues::new(2, None);
        for seq in 0..LANE_QUEUE_CAP as u64 {
            push_ok(&q, 1, lane_batch(QosClass::Standard, seq, false));
        }
        assert!(!q.has_room(1), "lane at capacity must report backed up");
        assert!(q.has_room(0), "other lanes are unaffected");
        let bounced = q.offer(1, lane_batch(QosClass::Standard, 99, false));
        let bounced = bounced.expect("offer to a full lane must bounce, not block");
        assert_eq!(bounced.batch_seq, 99, "the bounced batch comes back intact");
        // draining one batch reopens the lane for the retried offer
        let (first, _) = q.pop(1, false).expect("queued batch");
        assert_eq!(first.batch_seq, 0);
        assert!(q.has_room(1));
        push_ok(&q, 1, bounced);
    }

    /// A dead lane must swallow offers (error-replying their requests)
    /// instead of blocking the dispatcher forever.
    #[test]
    fn lane_queues_drop_offers_to_dead_lanes() {
        let q = LaneQueues::new(2, None);
        q.mark_dead(1);
        assert!(q.has_room(1), "dead lane reports ready so offers reach the drop path");
        for seq in 0..(LANE_QUEUE_CAP as u64 + 3) {
            // must neither block nor bounce — the batch is dropped
            assert!(q.offer(1, lane_batch(QosClass::Standard, seq, false)).is_none());
        }
        q.close();
        assert!(q.pop(1, false).is_none());
    }

    #[test]
    fn lane_set_ladders_fall_back_through_safer_classes() {
        let set = LaneSet::from_steps(
            LaneStep::uniform(9, 9),
            LaneStep::uniform(7, 7),
            LaneStep::uniform(5, 5),
            Some(LaneStep::uniform(4, 4)),
        );
        assert_eq!(set.gold.ladder.len(), 2, "gold: own + paper fallback");
        assert_eq!(set.standard.ladder.len(), 3);
        assert_eq!(set.economy.ladder.len(), 4);
        let shed = set.shed.as_ref().unwrap();
        assert_eq!(shed.ladder.len(), 5);
        // economy's next-safer rung is standard's operating point
        assert_eq!(set.economy.ladder[1].label, "uniform7/7");
        // every ladder terminates at the unmonitored paper default
        for spec in [&set.gold, &set.standard, &set.economy, shed] {
            let last = spec.ladder.last().unwrap();
            assert_eq!(last.label, "uniform8/8");
            assert!(last.predicted_snr_db.is_nan());
        }
    }

    #[test]
    fn lane_set_dedups_identical_neighbour_steps() {
        let set = LaneSet::from_steps(
            LaneStep::uniform(8, 8), // == paper default → no extra fallback rung
            LaneStep::uniform(8, 8),
            LaneStep::uniform(5, 5),
            None,
        );
        assert_eq!(set.gold.ladder.len(), 1, "own step == paper default → no extra fallback");
        assert_eq!(set.standard.ladder.len(), 1, "standard == gold == fallback → single rung");
        assert_eq!(set.economy.ladder.len(), 2, "standard/gold/fallback collapse to one rung");
        assert!(set.shed.is_none());
    }

    /// A lane whose measured SNR violates its (impossible) predicted
    /// bound hot-swaps to the next-safer rung between batches.
    #[test]
    fn lane_hot_swaps_on_forced_violation() {
        let model = tiny_model(3);
        let cache = WeightCache::shared();
        let spec = LaneSpec::new(vec![
            LaneStep::new(LayerSchedule::uniform(BfpConfig::new(4, 4)), 1000.0, "impossible"),
            LaneStep::uniform(8, 8),
        ]);
        let mcfg =
            MonitorConfig { sample_every: 1, min_probes: 1, margin_db: 0.0, ..Default::default() };
        let mut lane = Lane::new("economy", model.clone(), &spec, &cache, mcfg);
        assert_eq!(lane.pos, 0);
        let (out_noisy, probe) = lane.forward(vec![image(5)]);
        assert_eq!(lane.pos, 0, "probe (and any swap) must wait until responses are out");
        let (idx, probe_img) = probe.expect("sample_every=1 probes every batch");
        lane.probe(probe_img, &out_noisy[idx]);
        assert_eq!(lane.pos, 1, "violation did not trigger the hot-swap");
        assert_eq!(lane.swaps, 1);
        assert_eq!(lane.monitor.probes(), 0, "probe window must reset after a swap");
        // post-swap batches run the safer schedule, bit-identical to a
        // standalone prepared model on that schedule
        let (out_safe, probe2) = lane.forward(vec![image(5)]);
        // the safer rung carries no finite bound → probing never swaps again
        let (idx2, img2) = probe2.unwrap();
        lane.probe(img2, &out_safe[idx2]);
        assert_eq!((lane.pos, lane.swaps), (1, 1));
        let safer = PreparedModel::new(model, LayerSchedule::uniform(BfpConfig::new(8, 8)));
        let reference = safer.forward(&image(5));
        for (a, b) in reference.data.iter().zip(&out_safe[0].data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // and the pre-swap output really was the noisy plan
        assert_ne!(
            out_noisy[0].data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            out_safe[0].data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn lane_at_top_of_ladder_stays_put() {
        let model = tiny_model(4);
        let cache = WeightCache::shared();
        let spec = LaneSpec::new(vec![LaneStep::new(
            LayerSchedule::uniform(BfpConfig::new(4, 4)),
            1000.0,
            "impossible",
        )]);
        let mcfg =
            MonitorConfig { sample_every: 1, min_probes: 1, margin_db: 0.0, ..Default::default() };
        let mut lane = Lane::new("gold", model, &spec, &cache, mcfg);
        let (out, probe) = lane.forward(vec![image(6)]);
        let (idx, img) = probe.unwrap();
        lane.probe(img, &out[idx]);
        assert_eq!(lane.pos, 0);
        assert_eq!(lane.swaps, 0, "single-rung ladder cannot swap");
    }

    /// The probe position rotates across a lane's sampled batches
    /// instead of pinning itself to the most-urgent image 0.
    #[test]
    fn lane_probe_position_covers_the_batch() {
        let model = tiny_model(9);
        let cache = WeightCache::shared();
        let spec = LaneSpec::new(vec![LaneStep::uniform(8, 8)]);
        let mcfg =
            MonitorConfig { sample_every: 1, min_probes: 1, margin_db: 0.0, ..Default::default() };
        let mut lane = Lane::new("gold", model, &spec, &cache, mcfg);
        let mut seen = Vec::new();
        for round in 0..6 {
            let batch: Vec<Tensor> = (0..3).map(|i| image(100 + round * 3 + i)).collect();
            let (outputs, probe) = lane.forward(batch);
            let (idx, img) = probe.expect("sample_every=1");
            // the ticket's image is the one at the rotated position
            assert_eq!(outputs.len(), 3);
            seen.push(idx);
            lane.probe(img, &outputs[idx]);
        }
        assert_eq!(seen, vec![0, 1, 2, 0, 1, 2], "probe index must cycle the batch");
    }

    /// Satellite regression for the per-response timing skew: every
    /// response of a batch derives latency and deadline-miss from ONE
    /// completion instant, so requests sharing a deadline can never
    /// disagree on miss status because later sends absorbed
    /// metrics/channel time.
    #[test]
    fn batch_responses_share_one_completion_instant() {
        let model = tiny_model(11);
        let cache = WeightCache::shared();
        let spec = LaneSpec::new(vec![LaneStep::uniform(8, 8)]);
        let mcfg = MonitorConfig { sample_every: 0, ..Default::default() };
        let mut lane = Lane::new("gold", model, &spec, &cache, mcfg);

        let enqueued_at = Instant::now();
        // a deadline the forward may or may not beat — the point is that
        // whichever way it lands, every member must land the same way
        let deadline = enqueued_at + Duration::from_micros(300);
        let mut rxs = Vec::new();
        let mut meta = Vec::new();
        let mut images = Vec::new();
        for id in 0..4u64 {
            let (tx, rx) = channel();
            rxs.push(rx);
            meta.push(ResponseMeta { id, respond: tx, enqueued_at, deadline });
            images.push(image(40 + id));
        }
        let batch =
            LaneBatch { class: QosClass::Gold, batch_seq: 1, downgraded: false, images, meta };
        let global = Mutex::new(Metrics::default());
        let mut scratch = Metrics::default();
        let completed =
            deliver_batch(&mut lane, batch, &mut scratch, &global, None).expect("no panic");

        let responses: Vec<QosResponse> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        let want_missed = completed > deadline;
        for r in &responses {
            assert_eq!(
                r.deadline_missed, want_missed,
                "response {} disagrees with the batch completion instant",
                r.id
            );
        }
        // identical enqueue instants ⇒ identical derived latencies; the
        // old per-response elapsed() made these strictly increasing
        let m = global.lock().unwrap();
        let gold = m.class("gold").expect("batch recorded");
        assert_eq!(gold.requests, 4);
        assert_eq!(gold.deadline_misses, if want_missed { 4 } else { 0 });
        assert_eq!(scratch.total_requests, 0, "scratch must be cleared after the fold");
    }

    /// Numeric guard rail: a forward whose logits overflow to Inf is
    /// *corrupt output*, not a crash — the batch fails with a typed
    /// `CorruptOutput`, the counter bumps, and the lane hot-swaps one
    /// rung safer while staying live (no `LaneFailure`, no respawn).
    #[test]
    fn non_finite_logits_fail_typed_and_swap_the_lane_safer() {
        let mut rng = crate::data::Rng::new(3);
        let mut conv = crate::models::init::conv2d("c1", 4, 2, 3, 3, 1, 1, &mut rng);
        for w in conv.weights.data.iter_mut() {
            *w = 1.0e30; // finite weights whose products overflow f32
        }
        let model = Model {
            name: "overflow".into(),
            graph: Block::seq(vec![Block::Conv(conv), Block::Flatten]),
            input_shape: vec![2, 8, 8],
            num_classes: 0,
        };
        let cache = WeightCache::shared();
        let spec = LaneSpec::new(vec![LaneStep::uniform(6, 6), LaneStep::uniform(8, 8)]);
        let mcfg = MonitorConfig { sample_every: 0, ..Default::default() };
        let mut lane = Lane::new("gold", model, &spec, &cache, mcfg);

        let enqueued_at = Instant::now();
        let deadline = enqueued_at + Duration::from_secs(5);
        let (tx, rx) = channel();
        let meta = vec![ResponseMeta { id: 7, respond: tx, enqueued_at, deadline }];
        let images = vec![Tensor::from_vec(vec![1.0e10; 2 * 8 * 8], &[2, 8, 8])];
        let batch =
            LaneBatch { class: QosClass::Gold, batch_seq: 1, downgraded: false, images, meta };
        let global = Mutex::new(Metrics::default());
        let mut scratch = Metrics::default();
        deliver_batch(&mut lane, batch, &mut scratch, &global, None)
            .expect("corrupt output is a data problem, not an executor crash");

        let err = rx.recv().expect("poisoned batch must resolve").unwrap_err();
        assert_eq!(err.kind, QosErrorKind::CorruptOutput);
        assert_eq!(err.class, QosClass::Gold);
        assert!(err.message.contains("non-finite"), "message: {}", err.message);
        let m = global.lock().unwrap();
        assert_eq!(m.corrupt_outputs, 1, "guard must count once per batch");
        assert_eq!(m.class("gold").unwrap().failures, 1);
        assert_eq!(lane.pos, 1, "guard must move the lane one rung safer");
        assert_eq!(lane.swaps, 1);
    }

    /// End-to-end smoke over the tiny model: three classes, responses for
    /// everyone, per-class metrics populated — in every worker mode.
    #[test]
    fn qos_server_serves_all_classes_in_every_worker_mode() {
        for workers in [
            WorkerMode::Single,
            WorkerMode::PerLane { steal: true },
            WorkerMode::PerLane { steal: false },
        ] {
            let set = LaneSet::from_steps(
                LaneStep::uniform(9, 9),
                LaneStep::uniform(7, 7),
                LaneStep::uniform(5, 5),
                None,
            );
            let config = QosConfig {
                policy: BatchPolicy { max_batch: 4, linger: Duration::from_millis(2) },
                shed: ShedPolicy { enabled: false, queue_pressure: 0 },
                monitor: MonitorConfig { sample_every: 0, ..Default::default() },
                workers,
                faults: None,
                ..QosConfig::default()
            };
            let mut server = QosServer::start(tiny_model(8), &set, config);
            let mut pending = Vec::new();
            for i in 0..9u64 {
                let class = QosClass::ALL[(i % 3) as usize];
                pending.push((class, server.submit(class, image(50 + i)).unwrap()));
            }
            for (class, rx) in pending {
                let resp = rx.recv().expect("response").expect("served ok");
                assert_eq!(resp.class, class);
                assert_eq!(
                    resp.served_by,
                    class.name(),
                    "downgrade with shedding disabled ({})",
                    workers.name()
                );
                assert!(!resp.downgraded);
                assert_eq!(resp.logits.shape, vec![3 * 8 * 8]);
            }
            let report = server.shutdown();
            assert!(!report.worker_panic);
            assert_eq!(report.metrics.total_requests, 9, "mode {}", workers.name());
            for class in QosClass::ALL {
                let cm = report.metrics.class(class.name()).expect("class metrics");
                assert_eq!(cm.requests, 3, "mode {}", workers.name());
                assert_eq!(cm.downgrades, 0);
            }
            assert_eq!(report.lanes.len(), 3, "mode {}", workers.name());
            assert!(report.lanes.iter().all(|l| l.swaps == 0));
        }
    }

    fn plain_set() -> LaneSet {
        LaneSet::from_steps(
            LaneStep::uniform(8, 8),
            LaneStep::uniform(8, 8),
            LaneStep::uniform(8, 8),
            None,
        )
    }

    fn resilience_config(workers: WorkerMode) -> QosConfig {
        QosConfig {
            policy: BatchPolicy { max_batch: 1, linger: Duration::ZERO },
            shed: ShedPolicy { enabled: false, queue_pressure: 0 },
            monitor: MonitorConfig { sample_every: 0, ..Default::default() },
            workers,
            restart_backoff: Duration::from_millis(1),
            faults: None,
            ..QosConfig::default()
        }
    }

    /// A request whose image violates the model input shape panics the
    /// lane executor; the supervisor must error-reply the poisoned batch,
    /// respawn the lane, and keep serving — no permanently dead fabric.
    #[test]
    fn panicked_executor_respawns_and_keeps_serving() {
        let mut server =
            QosServer::start(tiny_model(8), &plain_set(), resilience_config(WorkerMode::Single));
        // poison pill: wrong input shape panics the executor mid-forward
        let rx = server.submit(QosClass::Gold, Tensor::zeros(&[1, 2, 2])).unwrap();
        let err = rx.recv().expect("supervised batch must resolve").unwrap_err();
        assert_eq!(err.kind, QosErrorKind::ExecutorPanic);
        assert_eq!(err.class, QosClass::Gold);
        // the respawned lane serves the very next request
        let ok = server.infer(QosClass::Gold, image(1)).expect("respawned lane serves");
        assert_eq!(ok.served_by, "gold");
        let gold = server.health().into_iter().find(|l| l.label == "gold").unwrap();
        assert!(gold.restarts >= 1, "health must report the respawn");
        assert!(!gold.retired);
        let report = server.shutdown();
        assert!(!report.worker_panic, "supervision keeps the worker alive");
        assert_eq!(report.metrics.total_requests, 1, "only the served request counts");
        assert_eq!(report.metrics.class("gold").unwrap().failures, 1);
        assert!(report.metrics.lane_restarts >= 1);
        assert_eq!(report.lanes.len(), 3, "every lane reports, poisoned one included");
        let lane = report.lanes.iter().find(|l| l.label == "gold").unwrap();
        assert!(lane.restarts >= 1);
        assert!(!lane.retired);
    }

    /// Exhausting the restart budget retires the lane; its traffic is
    /// permanently re-routed to the adjacent safer lane (which is not a
    /// downgrade), and the partial report stays complete.
    #[test]
    fn exhausted_restart_budget_retires_the_lane() {
        let config = QosConfig { restart_budget: 0, ..resilience_config(WorkerMode::Single) };
        let mut server = QosServer::start(tiny_model(8), &plain_set(), config);
        // budget 0: the first panic retires the economy lane outright
        let rx = server.submit(QosClass::Economy, Tensor::zeros(&[1, 2, 2])).unwrap();
        let err = rx.recv().expect("poisoned batch must resolve").unwrap_err();
        assert_eq!(err.kind, QosErrorKind::ExecutorPanic);
        let retired = (0..100).find(|_| {
            std::thread::sleep(Duration::from_millis(1));
            server.health().iter().any(|l| l.label == "economy" && l.retired)
        });
        assert!(retired.is_some(), "economy lane must show up retired in health");
        // traffic re-routes to the adjacent safer lane, not flagged as a
        // downgrade: a safer plan is a strict upgrade for the client
        let resp = server.infer(QosClass::Economy, image(3)).expect("re-routed request");
        assert_eq!(resp.served_by, "standard");
        assert!(!resp.downgraded, "a safer re-route is not a downgrade");
        let report = server.shutdown();
        assert_eq!(report.metrics.lanes_retired, 1);
        assert_eq!(report.metrics.lane_restarts, 0, "budget 0 means no respawns");
        assert_eq!(report.lanes.len(), 3, "retired lanes still report");
        let lane = report.lanes.iter().find(|l| l.label == "economy").unwrap();
        assert!(lane.retired);
        assert_eq!(lane.plan, "retired");
    }

    /// With the reaper armed, a request queued past `deadline + grace`
    /// fails with a typed `Timeout` instead of occupying a batch.
    #[test]
    fn reaper_times_out_expired_requests() {
        let faults = FaultInjector::parse("delay:gold:30:1", 0).unwrap();
        let config = QosConfig {
            reap_grace: Some(Duration::ZERO),
            faults: Some(Arc::new(faults)),
            ..resilience_config(WorkerMode::Single)
        };
        let mut server = QosServer::start(tiny_model(8), &plain_set(), config);
        // the gold request holds the single worker for ~30ms...
        let slow = server.submit(QosClass::Gold, image(7)).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        // ...so an already-expired economy request must be reaped, not served
        let doomed = server
            .submit_with_deadline(QosClass::Economy, image(8), Duration::ZERO)
            .unwrap();
        let ok = slow.recv().unwrap().expect("delayed gold request still serves");
        assert_eq!(ok.served_by, "gold");
        let err = doomed.recv().expect("reaped request must resolve").unwrap_err();
        assert_eq!(err.kind, QosErrorKind::Timeout);
        assert_eq!(err.class, QosClass::Economy);
        let report = server.shutdown();
        assert_eq!(report.metrics.class("economy").unwrap().timeouts, 1);
        assert_eq!(report.metrics.total_requests, 1, "reaped requests are not served");
    }

    /// Graceful drain: every pending request resolves — served, or failed
    /// with a typed `Draining` error once the bound expires — and new
    /// submits are refused immediately.
    #[test]
    fn drain_resolves_every_pending_request() {
        let mut server =
            QosServer::start(tiny_model(8), &plain_set(), resilience_config(WorkerMode::Single));
        let mut pending = Vec::new();
        for i in 0..12u64 {
            let class = QosClass::ALL[(i % 3) as usize];
            pending.push(server.submit(class, image(60 + i)).unwrap());
        }
        server.begin_drain(Duration::ZERO);
        assert!(server.submit(QosClass::Gold, image(99)).is_err(), "drain must refuse new work");
        let mut served = 0u64;
        let mut drained = 0u64;
        for rx in pending {
            match rx.recv().expect("drain must resolve every request") {
                Ok(_) => served += 1,
                Err(e) => {
                    assert_eq!(e.kind, QosErrorKind::Draining);
                    drained += 1;
                }
            }
        }
        assert_eq!(served + drained, 12, "no request may vanish during drain");
        let report = server.shutdown();
        assert_eq!(report.metrics.total_requests, served);
        let failed: u64 = QosClass::ALL
            .iter()
            .filter_map(|c| report.metrics.class(c.name()))
            .map(|cm| cm.failures)
            .sum();
        assert_eq!(failed, drained, "drained requests must be accounted as failures");
    }

    /// The stats board reports every lane's ladder position from the
    /// moment the server starts — before any batch has been served —
    /// and keeps the `health` fields in agreement.
    #[test]
    fn stats_snapshot_reports_rungs() {
        let mut server =
            QosServer::start(tiny_model(8), &plain_set(), resilience_config(WorkerMode::Single));
        let stats = server.stats();
        assert_eq!(stats.len(), 3);
        for lane in &stats {
            assert_eq!(lane.rung, 1, "{}: fresh lanes sit on their frontier rung", lane.label);
            assert!(lane.ladder >= 1, "{}", lane.label);
            assert!(!lane.retired);
            assert_eq!((lane.swaps, lane.promotions, lane.restarts), (0, 0, 0));
        }
        // rung stays published (and consistent with health) after serving
        let resp = server.infer(QosClass::Gold, image(2)).expect("served");
        assert_eq!(resp.served_by, "gold");
        let stats = server.stats();
        let health = server.health();
        let gold = stats.iter().find(|l| l.label == "gold").unwrap();
        assert_eq!(gold.rung, 1);
        assert_eq!(gold.ladder as usize, plain_set().gold.ladder.len());
        let gold_health = health.iter().find(|l| l.label == "gold").unwrap();
        assert_eq!(gold.restarts, gold_health.restarts);
        assert_eq!(gold.retired, gold_health.retired);
        server.shutdown();
    }
}
