//! QoS precision router: multi-lane serving with per-class precision
//! plans, deadline-aware scheduling and online NSR telemetry.
//!
//! The paper's result is that BFP mantissa width trades accuracy for
//! hardware cost along a curve the NSR bound predicts — which makes
//! precision a *runtime resource*. This module turns that knob into a
//! serving fabric:
//!
//! * Every request carries a [`QosClass`] (`Gold`/`Standard`/`Economy`)
//!   and an absolute deadline (explicit, or the class default).
//! * The server runs one *lane* per class — a
//!   [`PreparedModel`] bound to that class's precision plan, all lanes
//!   built over **one** [`SharedWeightCache`] so a weight format used by
//!   two lanes is quantized once, not once per lane.
//! * A deadline-aware scheduler extends the dynamic batcher: per-class
//!   earliest-deadline-first queues, batches are **never** mixed across
//!   classes (the lanes run different plans), linger is anchored to the
//!   head request's enqueue time, and under queue pressure the
//!   admission/shed policy routes non-`Gold` traffic to the next-cheaper
//!   lane (recording the downgrade) instead of blowing `Gold` deadlines.
//! * Each lane carries an online [`NsrMonitor`]
//!   ([`crate::telemetry`]): sampled BFP-vs-f32 probe forwards stream
//!   into a Welford accumulator, and when the measured SNR falls below
//!   the plan's predicted §4 bound the lane hot-swaps to the next-safer
//!   step of its precision ladder through the existing schedule-swap
//!   path — without dropping a single in-flight request.

use super::batcher::BatchPolicy;
use super::metrics::Metrics;
use crate::autotune::PrecisionPlan;
use crate::models::Model;
use crate::nn::prepared::{PreparedModel, SharedWeightCache, WeightCache};
use crate::nn::Fp32Exec;
use crate::quant::{BfpConfig, LayerSchedule};
use crate::telemetry::{MonitorConfig, NsrMonitor, Verdict};
use crate::tensor::Tensor;
use std::collections::BinaryHeap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A request's latency/quality class. `Gold` buys the safest precision
/// plan and the tightest deadline; `Economy` the cheapest plan and the
/// loosest deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QosClass {
    Gold,
    Standard,
    Economy,
}

impl QosClass {
    pub const ALL: [QosClass; 3] = [QosClass::Gold, QosClass::Standard, QosClass::Economy];

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Gold => "gold",
            QosClass::Standard => "standard",
            QosClass::Economy => "economy",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "gold" => Some(QosClass::Gold),
            "standard" => Some(QosClass::Standard),
            "economy" => Some(QosClass::Economy),
            _ => None,
        }
    }

    /// Deadline applied when a request does not carry its own.
    pub fn default_deadline(self) -> Duration {
        match self {
            QosClass::Gold => Duration::from_millis(25),
            QosClass::Standard => Duration::from_millis(100),
            QosClass::Economy => Duration::from_millis(400),
        }
    }

    /// Lane index: 0 = safest/most expensive, rising toward cheap.
    fn rank(self) -> usize {
        match self {
            QosClass::Gold => 0,
            QosClass::Standard => 1,
            QosClass::Economy => 2,
        }
    }
}

/// One rung of a lane's precision ladder: the schedule to execute plus
/// the predicted §4 SNR bound telemetry judges it against (NaN ⇒
/// unmonitored — e.g. the uniform paper-default fallback).
#[derive(Debug, Clone)]
pub struct LaneStep {
    pub schedule: LayerSchedule,
    pub predicted_snr_db: f64,
    pub label: String,
}

impl LaneStep {
    pub fn new(schedule: LayerSchedule, predicted_snr_db: f64, label: impl Into<String>) -> Self {
        Self { schedule, predicted_snr_db, label: label.into() }
    }

    /// A step executing an autotuned plan, bounded by its §4 prediction.
    pub fn from_plan(plan: &PrecisionPlan) -> Self {
        Self::new(
            plan.to_schedule(),
            plan.predicted_snr_db,
            format!("plan[{:.1}dB]", plan.predicted_snr_db),
        )
    }

    /// The ultimate fallback: the paper's uniform 8/8, unmonitored.
    pub fn uniform_paper() -> Self {
        Self::new(LayerSchedule::uniform(BfpConfig::paper_default()), f64::NAN, "uniform8/8")
    }

    /// An unmonitored uniform-width step (CLI `gold=9/9` syntax, tests).
    pub fn uniform(l_w: u32, l_i: u32) -> Self {
        let schedule = LayerSchedule::uniform(BfpConfig::new(l_w, l_i));
        Self::new(schedule, f64::NAN, format!("uniform{l_w}/{l_i}"))
    }
}

/// One lane's full precision ladder, operating point first, safer rungs
/// after — the hot-swap path walks toward the back.
#[derive(Debug, Clone)]
pub struct LaneSpec {
    pub ladder: Vec<LaneStep>,
}

impl LaneSpec {
    pub fn new(ladder: Vec<LaneStep>) -> Self {
        assert!(!ladder.is_empty(), "a lane needs at least one precision step");
        Self { ladder }
    }
}

/// The lane set of a QoS server: one lane per class plus an optional
/// *shed* lane below `Economy` that only downgraded traffic reaches.
#[derive(Debug, Clone)]
pub struct LaneSet {
    pub gold: LaneSpec,
    pub standard: LaneSpec,
    pub economy: LaneSpec,
    pub shed: Option<LaneSpec>,
}

impl LaneSet {
    /// Assemble the set from one operating step per lane. Ladders are
    /// derived automatically: each lane falls back through the safer
    /// classes' steps and terminates at the unmonitored uniform paper
    /// default (consecutive duplicate schedules collapse).
    pub fn from_steps(
        gold: LaneStep,
        standard: LaneStep,
        economy: LaneStep,
        shed: Option<LaneStep>,
    ) -> Self {
        fn ladder(own: &LaneStep, safer: &[&LaneStep]) -> Vec<LaneStep> {
            let mut steps = vec![own.clone()];
            for s in safer {
                if steps.last().unwrap().schedule != s.schedule {
                    steps.push((*s).clone());
                }
            }
            let fallback = LaneStep::uniform_paper();
            if steps.last().unwrap().schedule != fallback.schedule {
                steps.push(fallback);
            }
            steps
        }
        Self {
            gold: LaneSpec::new(ladder(&gold, &[])),
            standard: LaneSpec::new(ladder(&standard, &[&gold])),
            economy: LaneSpec::new(ladder(&economy, &[&standard, &gold])),
            shed: shed.map(|s| LaneSpec::new(ladder(&s, &[&economy, &standard, &gold]))),
        }
    }

    /// Build the set from autotuned plans, safest plan → `Gold`. With
    /// fewer plans than classes the cheapest plan is reused; a fourth
    /// plan becomes the shed lane.
    pub fn from_plans(plans: &[PrecisionPlan]) -> anyhow::Result<Self> {
        anyhow::ensure!(!plans.is_empty(), "lane set needs at least one precision plan");
        let mut sorted: Vec<&PrecisionPlan> = plans.iter().collect();
        sorted.sort_by(|a, b| b.predicted_snr_db.total_cmp(&a.predicted_snr_db));
        let step = |i: usize| LaneStep::from_plan(sorted[i.min(sorted.len() - 1)]);
        let shed = if sorted.len() > 3 { Some(step(3)) } else { None };
        Ok(Self::from_steps(step(0), step(1), step(2), shed))
    }
}

/// Outcome of one request through the QoS fabric.
#[derive(Debug, Clone)]
pub struct QosResponse {
    pub id: u64,
    pub logits: Tensor,
    /// The class the request asked for.
    pub class: QosClass,
    /// The lane that actually served it (differs from `class` on a
    /// downgrade).
    pub served_by: String,
    /// The active precision step of the serving lane.
    pub lane_plan: String,
    pub downgraded: bool,
    pub deadline_missed: bool,
    pub queue_wait: Duration,
    pub batch_size: usize,
    /// Monotone batch counter — responses sharing a `batch_seq` were
    /// served in the same batch (the class-purity invariant is asserted
    /// over this in the integration tests).
    pub batch_seq: u64,
}

/// Admission/shed policy: when the total backlog exceeds
/// `queue_pressure`, non-`Gold` batches route one lane cheaper
/// (`Standard` → economy lane, `Economy` → shed lane when configured).
/// `Gold` is never downgraded.
#[derive(Debug, Clone, Copy)]
pub struct ShedPolicy {
    pub enabled: bool,
    /// Backlog (requests still queued at batch dispatch) above which
    /// downgrade kicks in.
    pub queue_pressure: usize,
}

impl Default for ShedPolicy {
    fn default() -> Self {
        Self { enabled: true, queue_pressure: 32 }
    }
}

/// QoS server configuration.
#[derive(Debug, Clone, Copy)]
pub struct QosConfig {
    pub policy: BatchPolicy,
    pub shed: ShedPolicy,
    pub monitor: MonitorConfig,
}

impl Default for QosConfig {
    fn default() -> Self {
        Self {
            policy: BatchPolicy::default(),
            shed: ShedPolicy::default(),
            monitor: MonitorConfig::default(),
        }
    }
}

/// Pick the serving lane for a batch of `class` requests given the
/// backlog left in the queues. Returns `(lane index, downgraded)`.
fn route(class: QosClass, backlog: usize, shed: &ShedPolicy, lane_count: usize) -> (usize, bool) {
    let own = class.rank();
    if !shed.enabled || backlog <= shed.queue_pressure || class == QosClass::Gold {
        return (own, false);
    }
    let target = (own + 1).min(lane_count - 1);
    (target, target != own)
}

// ---- deadline-aware scheduling ---------------------------------------

struct QueuedRequest {
    id: u64,
    class: QosClass,
    image: Tensor,
    respond: Sender<QosResponse>,
    enqueued_at: Instant,
    deadline: Instant,
    /// Submission order; tie-break for equal deadlines (FIFO).
    seq: u64,
}

/// Max-heap entry ordered so the earliest deadline pops first.
struct EdfEntry(QueuedRequest);

impl PartialEq for EdfEntry {
    fn eq(&self, other: &Self) -> bool {
        self.0.deadline == other.0.deadline && self.0.seq == other.0.seq
    }
}
impl Eq for EdfEntry {}
impl PartialOrd for EdfEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for EdfEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap pops the max, we want the earliest deadline
        other.0.deadline.cmp(&self.0.deadline).then(other.0.seq.cmp(&self.0.seq))
    }
}

/// Per-class earliest-deadline-first queues. Batches are popped from one
/// class only — the no-mixing invariant is structural.
#[derive(Default)]
struct EdfQueues {
    heaps: [BinaryHeap<EdfEntry>; 3],
}

impl EdfQueues {
    fn push(&mut self, r: QueuedRequest) {
        self.heaps[r.class.rank()].push(EdfEntry(r));
    }

    fn is_empty(&self) -> bool {
        self.heaps.iter().all(|h| h.is_empty())
    }

    fn total(&self) -> usize {
        self.heaps.iter().map(|h| h.len()).sum()
    }

    fn class_len(&self, c: QosClass) -> usize {
        self.heaps[c.rank()].len()
    }

    /// EDF across classes: the class whose head request is most urgent.
    fn pick_class(&self) -> Option<QosClass> {
        QosClass::ALL
            .iter()
            .copied()
            .filter_map(|c| self.heaps[c.rank()].peek().map(|e| (e.0.deadline, e.0.seq, c)))
            .min_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)))
            .map(|(_, _, c)| c)
    }

    fn head_enqueued(&self, c: QosClass) -> Option<Instant> {
        self.heaps[c.rank()].peek().map(|e| e.0.enqueued_at)
    }

    /// Pop up to `max` requests of one class in deadline order.
    fn pop_batch(&mut self, c: QosClass, max: usize) -> Vec<QueuedRequest> {
        let heap = &mut self.heaps[c.rank()];
        let mut batch = Vec::with_capacity(max.min(heap.len()));
        while batch.len() < max {
            match heap.pop() {
                Some(EdfEntry(r)) => batch.push(r),
                None => break,
            }
        }
        batch
    }
}

// ---- lanes -----------------------------------------------------------

struct Lane {
    label: &'static str,
    prepared: PreparedModel,
    ladder: Vec<LaneStep>,
    pos: usize,
    monitor: NsrMonitor,
    swaps: u64,
    batches: u64,
}

impl Lane {
    fn new(
        label: &'static str,
        model: Model,
        spec: &LaneSpec,
        cache: &SharedWeightCache,
        monitor: MonitorConfig,
    ) -> Self {
        let prepared =
            PreparedModel::with_cache(model, spec.ladder[0].schedule.clone(), Arc::clone(cache));
        prepared.warm();
        Self {
            label,
            prepared,
            ladder: spec.ladder.clone(),
            pos: 0,
            monitor: NsrMonitor::new(monitor),
            swaps: 0,
            batches: 0,
        }
    }

    fn step(&self) -> &LaneStep {
        &self.ladder[self.pos]
    }

    /// Forward one class-pure batch. For a sampled batch the first image
    /// is returned as the telemetry probe input — the probe itself
    /// ([`Lane::probe`]) runs *after* the batch's responses have been
    /// sent, so its extra f32 reference forward never sits on the
    /// response path.
    fn forward(&mut self, images: Vec<Tensor>) -> (Vec<Tensor>, Option<Tensor>) {
        let probe_input = if self.monitor.tick_batch() { Some(images[0].clone()) } else { None };
        let outputs = self.prepared.forward_batch(images);
        self.batches += 1;
        (outputs, probe_input)
    }

    /// Telemetry probe for a sampled batch: run the f32 reference forward
    /// for `img`, fold the NSR against the lane's already-computed BFP
    /// output into the monitor, and hot-swap one rung safer on a bound
    /// violation.
    fn probe(&mut self, img: Tensor, bfp_output: &Tensor) {
        let reference = self.prepared.model().graph.execute(img, &mut Fp32Exec);
        self.monitor.record_probe(&reference.data, &bfp_output.data);
        if self.monitor.verdict(self.step().predicted_snr_db) == Verdict::Violation {
            self.swap_safer();
        }
    }

    /// Hot-swap to the next-safer ladder rung through the prepared
    /// model's schedule-swap path. In-flight batches are unaffected: the
    /// swap happens between batches on the serving thread, and queued
    /// requests simply execute under the safer schedule.
    fn swap_safer(&mut self) {
        if self.pos + 1 >= self.ladder.len() {
            return; // already at the safest rung
        }
        self.pos += 1;
        self.prepared.set_schedule(self.ladder[self.pos].schedule.clone());
        self.monitor.reset_probes();
        self.swaps += 1;
    }

    fn report(&self) -> LaneReport {
        LaneReport {
            label: self.label.to_string(),
            plan: self.step().label.clone(),
            predicted_snr_db: self.step().predicted_snr_db,
            measured_snr_db: self.monitor.measured_snr_db(),
            probes: self.monitor.probes(),
            batches: self.batches,
            swaps: self.swaps,
            ladder_pos: self.pos,
            ladder_len: self.ladder.len(),
        }
    }
}

/// Telemetry snapshot of one lane at shutdown.
#[derive(Debug, Clone)]
pub struct LaneReport {
    pub label: String,
    /// The precision step the lane ended on.
    pub plan: String,
    pub predicted_snr_db: f64,
    /// Streaming measured SNR since the last hot-swap (+∞ = no probes).
    pub measured_snr_db: f64,
    pub probes: u64,
    pub batches: u64,
    pub swaps: u64,
    pub ladder_pos: usize,
    pub ladder_len: usize,
}

/// Everything the QoS server knows at shutdown: per-class serving
/// metrics plus per-lane telemetry.
#[derive(Debug, Clone)]
pub struct QosReport {
    pub metrics: Metrics,
    pub lanes: Vec<LaneReport>,
}

// ---- the server ------------------------------------------------------

/// Handle to a running QoS precision router.
pub struct QosServer {
    tx: Option<Sender<QueuedRequest>>,
    worker: Option<JoinHandle<Vec<LaneReport>>>,
    metrics: Arc<Mutex<Metrics>>,
    next_id: u64,
    started: Instant,
}

impl QosServer {
    /// Build every lane over one shared weight cache and spawn the
    /// scheduler/worker thread.
    pub fn start(model: Model, set: &LaneSet, config: QosConfig) -> Self {
        let cache = WeightCache::shared();
        let mut lanes = vec![
            Lane::new("gold", model.clone(), &set.gold, &cache, config.monitor),
            Lane::new("standard", model.clone(), &set.standard, &cache, config.monitor),
            Lane::new("economy", model.clone(), &set.economy, &cache, config.monitor),
        ];
        if let Some(shed) = &set.shed {
            lanes.push(Lane::new("shed", model, shed, &cache, config.monitor));
        }

        let (tx, rx): (Sender<QueuedRequest>, Receiver<QueuedRequest>) = channel();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let metrics_worker = Arc::clone(&metrics);
        let worker = std::thread::spawn(move || run_worker(rx, lanes, config, metrics_worker));
        Self { tx: Some(tx), worker: Some(worker), metrics, next_id: 0, started: Instant::now() }
    }

    /// Submit one image under `class` with the class-default deadline.
    pub fn submit(&mut self, class: QosClass, image: Tensor) -> Receiver<QosResponse> {
        let deadline = class.default_deadline();
        self.submit_with_deadline(class, image, deadline)
    }

    /// Submit with an explicit per-request deadline (relative to now).
    pub fn submit_with_deadline(
        &mut self,
        class: QosClass,
        image: Tensor,
        deadline: Duration,
    ) -> Receiver<QosResponse> {
        let (tx, rx) = channel();
        self.next_id += 1;
        let now = Instant::now();
        self.tx
            .as_ref()
            .expect("server stopped")
            .send(QueuedRequest {
                id: self.next_id,
                class,
                image,
                respond: tx,
                enqueued_at: now,
                deadline: now + deadline,
                seq: self.next_id,
            })
            .expect("qos worker gone");
        rx
    }

    /// Submit and wait (tests / simple clients).
    pub fn infer(&mut self, class: QosClass, image: Tensor) -> QosResponse {
        self.submit(class, image).recv().expect("qos worker dropped response")
    }

    /// Snapshot of the metrics so far (the wall time keeps running).
    pub fn metrics(&self) -> Metrics {
        let mut m = self.metrics.lock().unwrap().clone();
        m.wall_time = self.started.elapsed();
        m
    }

    /// Drain the queues, stop the worker, and return the final report.
    pub fn shutdown(mut self) -> QosReport {
        drop(self.tx.take());
        let lanes = self
            .worker
            .take()
            .map(|w| w.join().expect("qos worker panicked"))
            .unwrap_or_default();
        let mut metrics = self.metrics.lock().unwrap().clone();
        metrics.wall_time = self.started.elapsed();
        QosReport { metrics, lanes }
    }
}

fn run_worker(
    rx: Receiver<QueuedRequest>,
    mut lanes: Vec<Lane>,
    config: QosConfig,
    metrics: Arc<Mutex<Metrics>>,
) -> Vec<LaneReport> {
    let mut queues = EdfQueues::default();
    let mut open = true;
    let mut batch_seq = 0u64;
    while open || !queues.is_empty() {
        if queues.is_empty() {
            match rx.recv() {
                Ok(r) => queues.push(r),
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        // drain everything already waiting in the channel
        while open {
            match rx.try_recv() {
                Ok(r) => queues.push(r),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => open = false,
            }
        }
        let Some(mut class) = queues.pick_class() else { continue };
        // linger anchored at the head request's enqueue time (not batch
        // start): a request that already waited its linger in the channel
        // closes the batch immediately
        if open && queues.class_len(class) < config.policy.max_batch {
            let anchor = queues.head_enqueued(class).expect("head exists") + config.policy.linger;
            loop {
                if queues.class_len(class) >= config.policy.max_batch {
                    break;
                }
                let now = Instant::now();
                if now >= anchor {
                    break;
                }
                match rx.recv_timeout(anchor - now) {
                    Ok(r) => queues.push(r),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            // linger arrivals may be more urgent — EDF re-pick
            class = queues.pick_class().expect("queues non-empty");
        }
        let batch = queues.pop_batch(class, config.policy.max_batch);
        let backlog = queues.total();
        let (lane_idx, downgraded) = route(class, backlog, &config.shed, lanes.len());
        let lane = &mut lanes[lane_idx];
        batch_seq += 1;
        let t0 = Instant::now();
        let batch_size = batch.len();
        let mut images = Vec::with_capacity(batch_size);
        let mut meta = Vec::with_capacity(batch_size);
        for r in batch {
            images.push(r.image);
            meta.push((r.id, r.respond, r.enqueued_at, r.deadline));
        }
        let (outputs, probe_img) = lane.forward(images);
        // retained for the post-response telemetry probe (logits are small)
        let probe_out = probe_img.as_ref().map(|_| outputs[0].clone());
        let served_by = lane.label.to_string();
        let lane_plan = lane.step().label.clone();
        for ((id, respond, enqueued_at, deadline), logits) in meta.into_iter().zip(outputs) {
            let queue_wait = t0.duration_since(enqueued_at);
            let latency = enqueued_at.elapsed();
            let deadline_missed = Instant::now() > deadline;
            metrics.lock().unwrap().record_class(
                class.name(),
                latency,
                queue_wait,
                batch_size,
                downgraded,
                deadline_missed,
            );
            let _ = respond.send(QosResponse {
                id,
                logits,
                class,
                served_by: served_by.clone(),
                lane_plan: lane_plan.clone(),
                downgraded,
                deadline_missed,
                queue_wait,
                batch_size,
                batch_seq,
            });
        }
        // responses are out — now the sampled probe (and a possible
        // hot-swap for the *next* batch) may spend its f32 forward
        if let (Some(img), Some(out)) = (probe_img, probe_out) {
            lane.probe(img, &out);
        }
    }
    lanes.iter().map(Lane::report).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Block;

    fn tiny_model(seed: u64) -> Model {
        let mut rng = crate::data::Rng::new(seed);
        Model {
            name: "tiny".into(),
            graph: Block::seq(vec![
                Block::Conv(crate::models::init::conv2d("c1", 4, 2, 3, 3, 1, 1, &mut rng)),
                Block::ReLU,
                Block::Conv(crate::models::init::conv2d("c2", 3, 4, 3, 3, 1, 1, &mut rng)),
                Block::Flatten,
            ]),
            input_shape: vec![2, 8, 8],
            num_classes: 0,
        }
    }

    fn image(seed: u64) -> Tensor {
        let mut rng = crate::data::Rng::new(seed);
        Tensor::from_vec(rng.normal_vec(2 * 8 * 8, 1.2), &[2, 8, 8])
    }

    fn queued(class: QosClass, seq: u64, deadline_ms: u64) -> QueuedRequest {
        let now = Instant::now();
        QueuedRequest {
            id: seq,
            class,
            image: Tensor::zeros(&[1, 1, 1]),
            respond: channel().0,
            enqueued_at: now,
            deadline: now + Duration::from_millis(deadline_ms),
            seq,
        }
    }

    #[test]
    fn edf_orders_within_class() {
        let mut q = EdfQueues::default();
        q.push(queued(QosClass::Gold, 1, 50));
        q.push(queued(QosClass::Gold, 2, 10));
        q.push(queued(QosClass::Gold, 3, 30));
        let batch = q.pop_batch(QosClass::Gold, 8);
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3, 1], "not earliest-deadline-first");
    }

    #[test]
    fn edf_picks_most_urgent_class() {
        let mut q = EdfQueues::default();
        q.push(queued(QosClass::Gold, 1, 100));
        q.push(queued(QosClass::Economy, 2, 5));
        assert_eq!(q.pick_class(), Some(QosClass::Economy));
        q.push(queued(QosClass::Gold, 3, 1));
        assert_eq!(q.pick_class(), Some(QosClass::Gold));
    }

    #[test]
    fn equal_deadlines_fall_back_to_fifo() {
        let mut q = EdfQueues::default();
        let base = Instant::now() + Duration::from_millis(50);
        for seq in 1..=3 {
            let mut r = queued(QosClass::Standard, seq, 0);
            r.deadline = base;
            q.push(r);
        }
        let ids: Vec<u64> = q.pop_batch(QosClass::Standard, 8).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn pop_batch_never_mixes_classes_and_respects_max() {
        let mut q = EdfQueues::default();
        for seq in 0..6 {
            q.push(queued(QosClass::Gold, seq, 10 + seq));
            q.push(queued(QosClass::Economy, 100 + seq, 10 + seq));
        }
        let batch = q.pop_batch(QosClass::Gold, 4);
        assert_eq!(batch.len(), 4, "max_batch cutoff");
        assert!(batch.iter().all(|r| r.class == QosClass::Gold), "classes mixed in a batch");
        assert_eq!(q.class_len(QosClass::Gold), 2);
        assert_eq!(q.class_len(QosClass::Economy), 6);
    }

    #[test]
    fn route_downgrades_only_under_pressure_and_never_gold() {
        let shed = ShedPolicy { enabled: true, queue_pressure: 4 };
        // no pressure: everyone stays home
        for c in QosClass::ALL {
            assert_eq!(route(c, 4, &shed, 4), (c.rank(), false));
        }
        // pressure: gold stays, standard → economy lane, economy → shed lane
        assert_eq!(route(QosClass::Gold, 100, &shed, 4), (0, false));
        assert_eq!(route(QosClass::Standard, 100, &shed, 4), (2, true));
        assert_eq!(route(QosClass::Economy, 100, &shed, 4), (3, true));
        // without a shed lane economy has nowhere cheaper to go
        assert_eq!(route(QosClass::Economy, 100, &shed, 3), (2, false));
        // disabled policy never downgrades
        let off = ShedPolicy { enabled: false, queue_pressure: 0 };
        assert_eq!(route(QosClass::Standard, 100, &off, 4), (1, false));
    }

    #[test]
    fn lane_set_ladders_fall_back_through_safer_classes() {
        let set = LaneSet::from_steps(
            LaneStep::uniform(9, 9),
            LaneStep::uniform(7, 7),
            LaneStep::uniform(5, 5),
            Some(LaneStep::uniform(4, 4)),
        );
        assert_eq!(set.gold.ladder.len(), 2, "gold: own + paper fallback");
        assert_eq!(set.standard.ladder.len(), 3);
        assert_eq!(set.economy.ladder.len(), 4);
        let shed = set.shed.as_ref().unwrap();
        assert_eq!(shed.ladder.len(), 5);
        // economy's next-safer rung is standard's operating point
        assert_eq!(set.economy.ladder[1].label, "uniform7/7");
        // every ladder terminates at the unmonitored paper default
        for spec in [&set.gold, &set.standard, &set.economy, shed] {
            let last = spec.ladder.last().unwrap();
            assert_eq!(last.label, "uniform8/8");
            assert!(last.predicted_snr_db.is_nan());
        }
    }

    #[test]
    fn lane_set_dedups_identical_neighbour_steps() {
        let set = LaneSet::from_steps(
            LaneStep::uniform(8, 8), // == paper default → no extra fallback rung
            LaneStep::uniform(8, 8),
            LaneStep::uniform(5, 5),
            None,
        );
        assert_eq!(set.gold.ladder.len(), 1, "own step == paper default → no extra fallback");
        assert_eq!(set.standard.ladder.len(), 1, "standard == gold == fallback → single rung");
        assert_eq!(set.economy.ladder.len(), 2, "standard/gold/fallback collapse to one rung");
        assert!(set.shed.is_none());
    }

    /// A lane whose measured SNR violates its (impossible) predicted
    /// bound hot-swaps to the next-safer rung between batches.
    #[test]
    fn lane_hot_swaps_on_forced_violation() {
        let model = tiny_model(3);
        let cache = WeightCache::shared();
        let spec = LaneSpec::new(vec![
            LaneStep::new(LayerSchedule::uniform(BfpConfig::new(4, 4)), 1000.0, "impossible"),
            LaneStep::uniform(8, 8),
        ]);
        let mcfg = MonitorConfig { sample_every: 1, min_probes: 1, margin_db: 0.0 };
        let mut lane = Lane::new("economy", model.clone(), &spec, &cache, mcfg);
        assert_eq!(lane.pos, 0);
        let (out_noisy, probe_img) = lane.forward(vec![image(5)]);
        assert_eq!(lane.pos, 0, "probe (and any swap) must wait until responses are out");
        lane.probe(probe_img.expect("sample_every=1 probes every batch"), &out_noisy[0]);
        assert_eq!(lane.pos, 1, "violation did not trigger the hot-swap");
        assert_eq!(lane.swaps, 1);
        assert_eq!(lane.monitor.probes(), 0, "probe window must reset after a swap");
        // post-swap batches run the safer schedule, bit-identical to a
        // standalone prepared model on that schedule
        let (out_safe, probe2) = lane.forward(vec![image(5)]);
        // the safer rung carries no finite bound → probing never swaps again
        lane.probe(probe2.unwrap(), &out_safe[0]);
        assert_eq!((lane.pos, lane.swaps), (1, 1));
        let safer = PreparedModel::new(model, LayerSchedule::uniform(BfpConfig::new(8, 8)));
        let reference = safer.forward(&image(5));
        for (a, b) in reference.data.iter().zip(&out_safe[0].data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // and the pre-swap output really was the noisy plan
        assert_ne!(
            out_noisy[0].data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            out_safe[0].data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn lane_at_top_of_ladder_stays_put() {
        let model = tiny_model(4);
        let cache = WeightCache::shared();
        let spec = LaneSpec::new(vec![LaneStep::new(
            LayerSchedule::uniform(BfpConfig::new(4, 4)),
            1000.0,
            "impossible",
        )]);
        let mcfg = MonitorConfig { sample_every: 1, min_probes: 1, margin_db: 0.0 };
        let mut lane = Lane::new("gold", model, &spec, &cache, mcfg);
        let (out, probe_img) = lane.forward(vec![image(6)]);
        lane.probe(probe_img.unwrap(), &out[0]);
        assert_eq!(lane.pos, 0);
        assert_eq!(lane.swaps, 0, "single-rung ladder cannot swap");
    }

    /// End-to-end smoke over the tiny model: three classes, responses for
    /// everyone, per-class metrics populated.
    #[test]
    fn qos_server_serves_all_classes() {
        let set = LaneSet::from_steps(
            LaneStep::uniform(9, 9),
            LaneStep::uniform(7, 7),
            LaneStep::uniform(5, 5),
            None,
        );
        let config = QosConfig {
            policy: BatchPolicy { max_batch: 4, linger: Duration::from_millis(2) },
            shed: ShedPolicy { enabled: false, queue_pressure: 0 },
            monitor: MonitorConfig { sample_every: 0, ..Default::default() },
        };
        let mut server = QosServer::start(tiny_model(8), &set, config);
        let mut pending = Vec::new();
        for i in 0..9u64 {
            let class = QosClass::ALL[(i % 3) as usize];
            pending.push((class, server.submit(class, image(50 + i))));
        }
        for (class, rx) in pending {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.class, class);
            assert_eq!(resp.served_by, class.name(), "downgrade with shedding disabled");
            assert!(!resp.downgraded);
            assert_eq!(resp.logits.shape, vec![3 * 8 * 8]);
        }
        let report = server.shutdown();
        assert_eq!(report.metrics.total_requests, 9);
        for class in QosClass::ALL {
            let cm = report.metrics.class(class.name()).expect("class metrics");
            assert_eq!(cm.requests, 3);
            assert_eq!(cm.downgrades, 0);
        }
        assert_eq!(report.lanes.len(), 3);
        assert!(report.lanes.iter().all(|l| l.swaps == 0));
    }
}
