//! Vendored, API-compatible subset of the `anyhow` crate.
//!
//! The build image has no crates.io access, so this path dependency
//! provides the slice of `anyhow` the workspace actually uses: [`Error`],
//! [`Result`], the [`Context`] extension trait (on `Result` and `Option`)
//! and the `anyhow!` / `bail!` / `ensure!` macros. Errors are carried as
//! rendered strings — source-chain downcasting is not supported (nothing
//! in this workspace uses it).

use std::fmt;

/// A string-backed error type mirroring `anyhow::Error`'s surface.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (`anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e:?}` / `{e:#}` on anyhow::Error print the message (+ chain);
        // we carry the chain pre-rendered inside `msg`.
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error (`anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {}", e.into())))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {}", f(), e.into())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...")` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "...")` — bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_even(s: &str) -> Result<u32> {
        let n: u32 = s.parse().context("not a number")?;
        ensure!(n % 2 == 0, "{n} is odd");
        Ok(n)
    }

    #[test]
    fn happy_path() {
        assert_eq!(parse_even("4").unwrap(), 4);
    }

    #[test]
    fn context_wraps_std_errors() {
        let e = parse_even("x").unwrap_err();
        assert!(e.to_string().starts_with("not a number: "), "{e}");
    }

    #[test]
    fn ensure_formats() {
        let e = parse_even("3").unwrap_err();
        assert_eq!(e.to_string(), "3 is odd");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e:?}"), "missing");
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<u32> {
            let n: u32 = "9x".parse()?;
            Ok(n)
        }
        assert!(inner().is_err());
    }
}
