//! `obs::clock::Clock::sleep` end to end: a real sleep before any mock
//! skew exists, then prompt wake-up of a far-future sleeper when the
//! mock clock advances.
//!
//! Lives in its own test binary on purpose: `Clock`'s skew is
//! process-global, so advancing it here must not share a process with
//! tests that assume real time.

use bfp_cnn::obs::clock::Clock;
use std::time::{Duration, Instant};

#[test]
fn sleep_tracks_real_time_then_wakes_on_advance() {
    // With no skew applied yet, Clock::sleep is an honest sleep.
    let t0 = Instant::now();
    Clock::sleep(Duration::from_millis(50));
    assert!(t0.elapsed() >= Duration::from_millis(45), "slept only {:?}", t0.elapsed());

    // A 30 s mocked sleep must return as soon as the clock jumps past
    // its deadline — not after 30 s of wall time.
    let t1 = Instant::now();
    let h = std::thread::spawn(|| Clock::sleep(Duration::from_secs(30)));
    std::thread::sleep(Duration::from_millis(200));
    // Keep advancing: the sleeper may compute its deadline before or
    // after any single advance lands, so one notify is not enough.
    for _ in 0..150 {
        Clock::advance(Duration::from_secs(31));
        if h.is_finished() {
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    h.join().expect("sleeper thread panicked");
    assert!(t1.elapsed() < Duration::from_secs(20), "mocked sleep took {:?}", t1.elapsed());
}
