//! End-to-end autotuner integration: a planned mixed-precision network
//! must beat the uniform 8-bit baseline on traffic at equal-or-better
//! measured NSR, and the coordinator engine must execute the plan
//! per-layer (DESIGN goal of ISSUE 1).

use bfp_cnn::autotune::{
    autotune_with_stats, calibrate, measure_schedule, plan_with_stats, PlannerOptions,
    PrecisionPlan,
};
use bfp_cnn::coordinator::engine::{forward_batch_ref, ExecMode};
use bfp_cnn::coordinator::server::{InferenceServer, RustBackend, ServerConfig};
use bfp_cnn::models::ModelId;
use bfp_cnn::quant::{BfpConfig, LayerSchedule};
use std::path::Path;

fn lenet() -> bfp_cnn::models::Model {
    ModelId::Lenet.build(32, 1, Path::new("artifacts"))
}

fn calib_images(n: usize, seed: u64) -> Vec<bfp_cnn::tensor::Tensor> {
    bfp_cnn::data::DigitDataset::generate(n, seed).images
}

/// Plan LeNet against the *measured* quality of uniform 8/8 and check the
/// acceptance criterion: fewer total mantissa bits, equal-or-better
/// measured conv-output NSR (small tolerance for measurement noise).
#[test]
fn planned_lenet_beats_uniform8_on_traffic_at_equal_nsr() {
    let model = lenet();
    let calib = calib_images(4, 99);
    let uni = measure_schedule(&model, &calib, &LayerSchedule::uniform(BfpConfig::paper_default()));
    let budget = uni.conv_out_snr_db;

    let opts = PlannerOptions::default();
    let convs = calibrate(&model, &calib, &opts).unwrap();
    let plan = autotune_with_stats(&model, &calib, &convs, budget, &opts);

    let uniform_traffic = plan.uniform_traffic_bits(8, 8);
    assert!(
        plan.total_traffic_bits() < uniform_traffic,
        "plan traffic {} !< uniform 8/8 traffic {uniform_traffic}",
        plan.total_traffic_bits()
    );
    assert!(
        plan.measured_snr_db >= budget - 0.75,
        "plan measured {} dB vs uniform 8/8 measured {budget} dB",
        plan.measured_snr_db
    );
}

/// The planned schedule must run end-to-end through coordinator::engine
/// and stay close to fp32 at the logits.
#[test]
fn engine_executes_plan_per_layer() {
    let model = lenet();
    let calib = calib_images(4, 123);
    let opts = PlannerOptions::default();
    let convs = calibrate(&model, &calib, &opts).unwrap();
    let plan = autotune_with_stats(&model, &calib, &convs, 26.0, &opts);
    assert!(plan.measured_snr_db >= 25.0, "plan misses budget: {} dB", plan.measured_snr_db);

    let eval = calib_images(6, 321);
    let fp = forward_batch_ref(&model, &eval, ExecMode::Fp32);
    let mixed = forward_batch_ref(&model, &eval, ExecMode::Mixed(plan.to_schedule()));
    assert_eq!(mixed.len(), 6);
    for (a, b) in fp.iter().zip(&mixed) {
        assert_eq!(b.shape, vec![10]);
        let nsr = a.data.iter().zip(&b.data).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
            / a.energy().max(1e-12);
        assert!(nsr < 0.25, "logits NSR {nsr} too high for a ≥26 dB conv budget");
    }
}

/// The serving stack accepts a mixed-precision backend.
#[test]
fn server_serves_mixed_precision_plan() {
    let model = lenet();
    let calib = calib_images(3, 5);
    let opts = PlannerOptions::default();
    let convs = calibrate(&model, &calib, &opts).unwrap();
    let plan = plan_with_stats("lenet", &convs, 28.0, &opts);

    let backend = RustBackend { model, mode: ExecMode::Mixed(plan.to_schedule()) };
    let mut server = InferenceServer::start(Box::new(backend), ServerConfig::default());
    let pending: Vec<_> =
        calib_images(5, 777).into_iter().map(|img| server.submit(img)).collect();
    for rx in pending {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits.shape, vec![10]);
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.total_requests, 5);
}

/// Plans survive the serialize → load → execute round trip (the
/// `bfp-cnn autotune --out` → `serve --mode plan` path).
#[test]
fn plan_file_round_trips_into_execution() {
    let model = lenet();
    let calib = calib_images(3, 8);
    let opts = PlannerOptions::default();
    let convs = calibrate(&model, &calib, &opts).unwrap();
    let plan = plan_with_stats("lenet", &convs, 30.0, &opts);

    let dir = std::env::temp_dir().join("bfp_cnn_autotune_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("lenet.plan");
    plan.save(&path).unwrap();
    let loaded = PrecisionPlan::load(&path).unwrap();
    // compare width assignments (measured fields are NaN; NaN != NaN)
    let key = |p: &PrecisionPlan| -> Vec<(String, u32, u32)> {
        p.layers.iter().map(|l| (l.name.clone(), l.l_w, l.l_i)).collect()
    };
    assert_eq!(key(&loaded), key(&plan));
    assert_eq!(loaded.to_schedule(), plan.to_schedule());

    let out = forward_batch_ref(&model, &calib, ExecMode::Mixed(loaded.to_schedule()));
    assert_eq!(out.len(), 3);
    std::fs::remove_file(&path).ok();
}

/// Cross-model smoke: the planner also handles a deep sequential net
/// (VGG-16 at reduced spatial size) and still beats uniform 8/8 traffic
/// at the uniform-8 predicted quality.
#[test]
fn vgg16_plan_saves_traffic_at_uniform8_prediction() {
    let model = ModelId::Vgg16.build(32, 1, Path::new("artifacts"));
    let calib = vec![bfp_cnn::data::imagenet_like_batch(1, 32, 3).remove(0)];
    let opts = PlannerOptions::default();
    let convs = calibrate(&model, &calib, &opts).unwrap();
    let budget = bfp_cnn::autotune::uniform_predicted_snr_db(&convs, 8);
    let plan = plan_with_stats("vgg16", &convs, budget, &opts);
    assert_eq!(plan.layers.len(), 13, "vgg16 has 13 conv layers");
    assert!(
        plan.total_traffic_bits() < plan.uniform_traffic_bits(8, 8),
        "vgg plan saves nothing"
    );
    assert!(plan.predicted_snr_db >= budget);
}
