//! Integration tests for the networked serving fabric (ISSUE 6
//! acceptance):
//!
//! (a) logits served over TCP are bit-identical to the in-process
//!     `QosServer::infer` path on the same model and lane set;
//! (b) the open-loop load generator measures from intended send — under
//!     saturation its latency is at least the closed-loop latency
//!     (closed loop politely hides the queue; open loop charges it);
//! (c) a client that stops reading only backpressures itself: other
//!     tenants' connections keep serving, and its own replies are all
//!     still there once it drains;
//! (d) per-tenant token-bucket quotas walk admit → degrade → reject in
//!     exactly the configured budget order, the degraded requests serve
//!     on the economy lane, an in-quota gold tenant is untouched, and
//!     the shutdown report carries the per-tenant accounting;
//! (e) hostile frames (garbage, wrong version, hostile length prefix)
//!     get error frames without wedging the connection — a valid
//!     request after an in-sync decode error is still served;
//! (f) resilience over the wire (PR 7): health frames report per-lane
//!     liveness, the retrying client survives seeded reset/truncated
//!     connections and CRC-failing corrupted reply frames counting its
//!     reconnects exactly, and the deadline reaper turns hopeless
//!     requests into typed `Timeout` error frames without wedging the
//!     connection.
//!
//! The suite honours `BFP_QOS_WORKERS` — CI runs it under both
//! schedulers, like `qos_integration` (and once more with `BFP_FAULTS`
//! arming benign delay injection).

use bfp_cnn::coordinator::batcher::BatchPolicy;
use bfp_cnn::coordinator::{LaneSet, LaneStep, QosClass, QosConfig, QosServer, ShedPolicy};
use bfp_cnn::models::ModelId;
use bfp_cnn::net::proto::{self, ErrorCode, Msg, NetRequest, Reply};
use bfp_cnn::net::{NetClient, NetServer, NetServerConfig, QuotaConfig};
use bfp_cnn::runtime::FaultInjector;
use bfp_cnn::telemetry::MonitorConfig;
use bfp_cnn::Tensor;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn lenet() -> bfp_cnn::models::Model {
    ModelId::Lenet.build(32, 1, Path::new("/nonexistent"))
}

fn images(n: usize, seed: u64) -> Vec<Tensor> {
    bfp_cnn::data::DigitDataset::generate(n, seed).images
}

fn demo_lane_set() -> LaneSet {
    LaneSet::from_steps(
        LaneStep::uniform(9, 9),
        LaneStep::uniform(7, 7),
        LaneStep::uniform(5, 5),
        None,
    )
}

/// Telemetry off, shedding off: pure routing (worker mode from the
/// environment, so CI's scheduler matrix applies here too).
fn quiet_config() -> QosConfig {
    QosConfig {
        policy: BatchPolicy { max_batch: 4, linger: Duration::from_millis(2) },
        shed: ShedPolicy { enabled: false, queue_pressure: 0 },
        monitor: MonitorConfig { sample_every: 0, ..Default::default() },
        ..QosConfig::default()
    }
}

/// Bind a loopback front over a fresh router. Connection faults stay
/// off so the protocol tests are exactly reproducible; lane-level
/// faults still arm from `BFP_FAULTS` through `quiet_config`.
fn start_front(quota: QuotaConfig) -> (NetServer, SocketAddr) {
    start_front_with(quiet_config(), quota, None)
}

fn start_front_with(
    config: QosConfig,
    quota: QuotaConfig,
    faults: Option<Arc<FaultInjector>>,
) -> (NetServer, SocketAddr) {
    let qos = QosServer::start(lenet(), &demo_lane_set(), config);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let net_config = NetServerConfig { max_conns: 32, quota, faults };
    let server = NetServer::start(listener, qos, net_config).expect("start net server");
    let addr = server.addr();
    (server, addr)
}

/// (a) the wire carries raw f32 bits, so TCP-served logits must match
/// the in-process path bit for bit, class by class.
#[test]
fn tcp_serving_is_bit_identical_to_in_process() {
    let imgs = images(9, 42);
    let classes: Vec<QosClass> = (0..imgs.len()).map(|i| QosClass::ALL[i % 3]).collect();

    // in-process reference on an identical (deterministically rebuilt)
    // model and lane set
    let mut reference = QosServer::start(lenet(), &demo_lane_set(), quiet_config());
    let want: Vec<Tensor> = imgs
        .iter()
        .zip(&classes)
        .map(|(img, &c)| reference.infer(c, img.clone()).expect("in-process serves").logits)
        .collect();
    reference.shutdown();

    let (server, addr) = start_front(QuotaConfig::default());
    let mut client = NetClient::connect(addr).expect("connect");
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    for (i, (img, &class)) in imgs.iter().zip(&classes).enumerate() {
        let resp = client.infer("acme", class, img.clone()).expect("tcp serves");
        assert_eq!(resp.class, class);
        assert_eq!(resp.served_by, class.name(), "no downgrades with shedding off");
        assert!(!resp.quota_downgraded, "unlimited quota must not degrade");
        assert_eq!(resp.logits.shape, want[i].shape);
        for (a, b) in want[i].data.iter().zip(&resp.logits.data) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "request {i}: TCP-served logits diverged from the in-process path"
            );
        }
    }
    drop(client);
    let report = server.shutdown();
    let acme = report.metrics.tenant("acme").expect("tenant accounting over TCP");
    assert_eq!(acme.requests, imgs.len() as u64);
    assert_eq!(acme.quota_downgrades + acme.rejected, 0);
}

/// (b) under saturation the open-loop (intended-send) latency must be
/// at least the closed-loop latency: the closed loop slows its offered
/// load to match the server, hiding the queueing the open loop charges.
#[test]
fn open_loop_latency_dominates_closed_loop_under_saturation() {
    use bfp_cnn::net::loadgen::{run_closed_loop, run_open_loop, RunOpts};

    let (server, addr) = start_front(QuotaConfig::default());
    let pool = images(4, 7);
    let opts = RunOpts { tenant: "sat".to_string(), ..RunOpts::default() };

    let closed = run_closed_loop(addr, &pool, 6, &opts, "sat-closed").expect("closed loop");
    assert_eq!(closed.ok, 6, "closed loop lost replies");

    // 32 arrivals 100 µs apart: far faster than a LeNet forward, so the
    // backlog grows and intended-send latency accumulates
    let offsets: Vec<Duration> =
        (0..32).map(|i| Duration::from_micros(100) * i as u32).collect();
    let open = run_open_loop(addr, &pool, &offsets, &opts, "sat-open").expect("open loop");
    assert_eq!(open.ok, 32, "open loop must get every reply (shedding is off)");
    assert_eq!(open.sent, 32);

    let (o50, c50) = (open.latency_p(50.0), closed.latency_p(50.0));
    assert!(
        o50 >= c50,
        "open-loop p50 {o50:.2} ms < closed-loop p50 {c50:.2} ms — \
         coordinated omission is back"
    );
    server.shutdown();
}

/// (c) a slow reader only backpressures itself: its replies queue in its
/// own per-connection channel/socket while another tenant's connection
/// keeps serving promptly, and the slow client still gets every reply
/// once it finally drains.
#[test]
fn slow_client_backpressure_does_not_block_other_tenants() {
    let (server, addr) = start_front(QuotaConfig::default());
    let imgs = images(8, 5);

    // sloth fires 8 requests and reads nothing
    let mut sloth = NetClient::connect(addr).expect("connect sloth");
    sloth.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    for img in &imgs {
        sloth.send("sloth", QosClass::Standard, None, img.clone()).expect("send");
    }

    // a concurrent gold tenant on its own connection must keep serving
    let mut probe = NetClient::connect(addr).expect("connect probe");
    probe.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    for img in imgs.iter().take(4) {
        let t0 = Instant::now();
        let resp = probe.infer("probe", QosClass::Gold, img.clone()).expect("gold serves");
        assert_eq!(resp.served_by, "gold");
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "gold inference stalled behind a slow client"
        );
    }

    // the sloth's replies were never lost — drain all 8 now
    let mut got = 0;
    while got < imgs.len() {
        match sloth.read_reply().expect("sloth drains") {
            Reply::Response(_) => got += 1,
            Reply::Error(e) => panic!("sloth request rejected: {e:?}"),
        }
    }
    server.shutdown();
}

/// (d) the token bucket walks admit → degrade → reject in budget order:
/// burst 2 admits, reject_debt 3 degrades (served on the economy lane,
/// flagged `quota_downgraded`), then hard rejects — while a second
/// tenant's gold traffic stays untouched and the report's per-tenant
/// counters match exactly.
#[test]
fn tenant_quota_degrades_then_sheds_without_starving_gold() {
    // ~zero refill rate: the budget is the burst plus the debt window
    let quota = QuotaConfig { rate_per_s: 0.001, burst: 2.0, reject_debt: 3.0 };
    let (server, addr) = start_front(quota);
    let imgs = images(8, 13);

    let mut abuser = NetClient::connect(addr).expect("connect abuser");
    abuser.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut vip = NetClient::connect(addr).expect("connect vip");
    vip.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    let mut admitted = 0u64;
    let mut degraded = 0u64;
    let mut rejected = 0u64;
    let mut ladder = Vec::new();
    for img in &imgs {
        abuser.send("abuser", QosClass::Standard, None, img.clone()).expect("send");
        match abuser.read_reply().expect("reply") {
            Reply::Response(resp) => {
                assert_eq!(resp.class, QosClass::Standard, "the response echoes the asked class");
                if resp.quota_downgraded {
                    degraded += 1;
                    ladder.push("degrade");
                    assert_eq!(resp.served_by, "economy", "over-quota serves on the cheap lane");
                    assert!(resp.downgraded);
                } else {
                    admitted += 1;
                    ladder.push("admit");
                    assert_eq!(resp.served_by, "standard");
                }
            }
            Reply::Error(err) => {
                rejected += 1;
                ladder.push("reject");
                assert_eq!(err.code, ErrorCode::OverQuota, "rejects carry OverQuota: {err:?}");
            }
        }
        // the vip's separate bucket keeps admitting at full class
        let resp = vip.infer("vip", QosClass::Gold, img.clone()).expect("vip serves");
        assert_eq!(resp.served_by, "gold", "gold tenant starved by an abuser");
        assert!(!resp.quota_downgraded);
    }
    assert_eq!(
        (admitted, degraded, rejected),
        (2, 3, 3),
        "budget order broke: {ladder:?}"
    );
    assert_eq!(
        ladder,
        ["admit", "admit", "degrade", "degrade", "degrade", "reject", "reject", "reject"],
        "the ladder must be monotone: admit, then degrade, then reject"
    );

    let report = server.shutdown();
    let ab = report.metrics.tenant("abuser").expect("abuser accounting");
    assert_eq!((ab.requests, ab.quota_downgrades, ab.rejected), (8, 3, 3));
    let vip_m = report.metrics.tenant("vip").expect("vip accounting");
    assert_eq!((vip_m.requests, vip_m.quota_downgrades, vip_m.rejected), (8, 0, 0));
}

/// (e) protocol robustness on a raw socket: garbage and version-mismatch
/// frames earn `BadRequest` error frames and the stream stays usable
/// (framing is intact), while a hostile length prefix kills exactly that
/// connection.
#[test]
fn hostile_frames_get_error_frames_and_framing_recovers() {
    let (server, addr) = start_front(QuotaConfig::default());
    let img = images(1, 3).remove(0);

    let mut stream = TcpStream::connect(addr).expect("connect raw");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = stream.try_clone().expect("clone");

    let expect_error = |reader: &mut TcpStream, code: ErrorCode, what: &str| {
        let payload = proto::read_frame(reader).expect(what).expect("frame, not EOF");
        match proto::decode(&payload).expect("server frames always decode") {
            Msg::Error(e) => assert_eq!(e.code, code, "{what}: {e:?}"),
            other => panic!("{what}: expected an error frame, got {other:?}"),
        }
    };

    // a well-framed payload of garbage: decode fails, stream stays in sync
    proto::write_frame(&mut stream, &[0xFF; 16]).expect("write garbage");
    expect_error(&mut reader, ErrorCode::BadRequest, "garbage payload");

    // a valid request re-encoded under the wrong protocol version
    let req = NetRequest {
        id: 1,
        tenant: "raw".to_string(),
        class: QosClass::Economy,
        deadline_us: 0,
        image: img.clone(),
    };
    let mut wrong_version = proto::encode_request(&req);
    wrong_version[0] = proto::PROTO_VERSION.wrapping_add(9);
    proto::write_frame(&mut stream, &wrong_version).expect("write bad version");
    expect_error(&mut reader, ErrorCode::BadRequest, "version mismatch");

    // the connection is still framed: a valid request now serves normally
    proto::write_frame(&mut stream, &proto::encode_request(&req)).expect("write valid");
    let payload = proto::read_frame(&mut reader).expect("read reply").expect("frame");
    match proto::decode(&payload).expect("decodes") {
        Msg::Response(resp) => {
            assert_eq!(resp.id, 1);
            assert!(!resp.logits.data.is_empty(), "resynced request must be served");
        }
        other => panic!("expected the served response, got {other:?}"),
    }

    // a hostile length prefix desyncs framing: error frame, then close
    let mut evil = TcpStream::connect(addr).expect("connect evil");
    evil.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    evil.write_all(&u32::MAX.to_le_bytes()).expect("write hostile length");
    evil.flush().unwrap();
    let mut evil_reader = evil.try_clone().expect("clone");
    expect_error(&mut evil_reader, ErrorCode::BadRequest, "hostile length prefix");
    assert!(
        proto::read_frame(&mut evil_reader).expect("clean close").is_none(),
        "the desynced connection must be closed, not resumed"
    );
    server.shutdown();
}

/// (f) the health frame: a fresh server reports every lane live with
/// zero restarts, in lane order, and the probe leaves the connection
/// perfectly usable for inference.
#[test]
fn health_frame_reports_live_lanes() {
    let (server, addr) = start_front(QuotaConfig::default());
    let mut client = NetClient::connect(addr).expect("connect");
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let health = client.health().expect("health frame");
    let labels: Vec<&str> = health.lanes.iter().map(|l| l.label.as_str()).collect();
    assert_eq!(labels, ["gold", "standard", "economy"], "one row per lane, safest first");
    for lane in &health.lanes {
        assert!(!lane.retired, "fresh lane {} reports retired", lane.label);
        assert_eq!(lane.restarts, 0, "fresh lane {} reports restarts", lane.label);
    }
    // the probe is a normal frame round trip: inference still works
    let resp = client.infer("probe", QosClass::Gold, images(1, 2).remove(0)).expect("serves");
    assert_eq!(resp.served_by, "gold");
    server.shutdown();
}

/// (f) transport-fault recovery: the server's fault plane resets the
/// first connection mid-round-trip and answers the second with a
/// truncated frame; the retrying client reconnects under jittered
/// backoff, resends, serves every request bit-normally, and counts
/// exactly the two reconnect cycles.
#[test]
fn retrying_client_survives_reset_and_truncated_connections() {
    use bfp_cnn::net::{RetryPolicy, RetryingClient};

    let faults = FaultInjector::parse("reset:conn:1,truncate:conn:2", 9).expect("spec parses");
    let (server, addr) =
        start_front_with(quiet_config(), QuotaConfig::default(), Some(Arc::new(faults)));

    let (base, cap) = (Duration::from_millis(5), Duration::from_millis(40));
    let policy = RetryPolicy { attempts: 4, base, cap };
    let mut client = RetryingClient::new(addr.to_string(), policy, 7);
    client.set_read_timeout(Some(Duration::from_secs(30)));
    let imgs = images(4, 21);
    for (i, img) in imgs.iter().enumerate() {
        let resp = client.infer("flaky", QosClass::Standard, img.clone()).expect("recovers");
        assert_eq!(resp.served_by, "standard", "request {i} downgraded");
    }
    assert_eq!(client.retries, 2, "exactly the two sabotaged connections cost a reconnect");
    // the surviving connection also answers health probes
    let health = client.health().expect("health over the recovered connection");
    assert!(health.lanes.iter().all(|l| !l.retired));
    server.shutdown();
}

/// (f) integrity over the wire: the server's fault plane answers the
/// first connection with a whole, well-framed reply whose payload had a
/// bit flipped after sealing. The length prefix is honest, so only the
/// trailing CRC betrays the damage — the retrying client must refuse
/// the frame, reconnect, and serve every request with logits identical
/// to a clean round trip, counting exactly one reconnect.
#[test]
fn retrying_client_refuses_a_corrupted_reply_frame() {
    use bfp_cnn::net::{RetryPolicy, RetryingClient};

    let imgs = images(3, 33);

    // clean reference logits on an identical deterministic stack
    let (clean_server, clean_addr) = start_front(QuotaConfig::default());
    let mut reference = NetClient::connect(clean_addr).expect("connect clean front");
    reference.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let want: Vec<Tensor> = imgs
        .iter()
        .map(|img| {
            reference.infer("ref", QosClass::Standard, img.clone()).expect("clean").logits
        })
        .collect();
    clean_server.shutdown();

    let faults = FaultInjector::parse("corrupt:frame:1", 5).expect("spec parses");
    let (server, addr) =
        start_front_with(quiet_config(), QuotaConfig::default(), Some(Arc::new(faults)));

    let (base, cap) = (Duration::from_millis(5), Duration::from_millis(40));
    let policy = RetryPolicy { attempts: 4, base, cap };
    let mut client = RetryingClient::new(addr.to_string(), policy, 11);
    client.set_read_timeout(Some(Duration::from_secs(30)));
    for (i, img) in imgs.iter().enumerate() {
        let resp = client.infer("ref", QosClass::Standard, img.clone()).expect("recovers");
        assert_eq!(
            resp.logits.data, want[i].data,
            "request {i} logits drifted across the retry"
        );
    }
    assert_eq!(client.retries, 1, "exactly the corrupted frame costs a reconnect");
    server.shutdown();
}

/// (f) the deadline reaper over the wire: with a zero grace, a burst of
/// 1 µs deadlines cannot all be served — the hopeless ones come back as
/// typed `Timeout` error frames, every request is answered one way or
/// the other, the accounting matches frame for frame, and the
/// connection serves a sane follow-up request afterwards.
#[test]
fn reaped_deadline_returns_timeout_error_frame() {
    let config = QosConfig { reap_grace: Some(Duration::ZERO), ..quiet_config() };
    let (server, addr) = start_front_with(config, QuotaConfig::default(), None);
    let mut client = NetClient::connect(addr).expect("connect");
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let imgs = images(8, 6);
    for img in &imgs {
        client
            .send("hasty", QosClass::Standard, Some(Duration::from_micros(1)), img.clone())
            .unwrap();
    }
    let mut served = 0u64;
    let mut reaped = 0u64;
    for _ in 0..imgs.len() {
        match client.read_reply().expect("every request is answered") {
            Reply::Response(resp) => {
                served += 1;
                assert!(resp.deadline_missed, "a 1 µs deadline cannot be met");
            }
            Reply::Error(e) => {
                reaped += 1;
                assert_eq!(e.code, ErrorCode::Timeout, "reaped requests carry Timeout: {e:?}");
            }
        }
    }
    assert_eq!(served + reaped, imgs.len() as u64, "a request went unanswered");
    assert!(reaped > 0, "an expired burst of 8 must see the reaper at least once");
    // the reaper kills requests, not connections
    let resp = client.infer("hasty", QosClass::Standard, imgs[0].clone()).expect("still serves");
    assert_eq!(resp.served_by, "standard");
    let report = server.shutdown();
    let cm = report.metrics.class("standard").expect("standard metrics");
    assert_eq!(cm.timeouts, reaped, "Timeout frames must match the reaper accounting");
}
