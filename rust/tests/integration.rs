//! Cross-module integration tests: trained artifacts → Rust engine →
//! harness → PJRT runtime (artifact-dependent tests skip gracefully when
//! `make artifacts` hasn't run, so plain `cargo test` stays green).

use bfp_cnn::coordinator::engine::{forward_batch_ref, ExecMode};
use bfp_cnn::harness::table3::{drop_for, eval_set_for};
use bfp_cnn::models::{weights_io::WeightBundle, ModelId};
use bfp_cnn::quant::BfpConfig;
use std::path::Path;

fn artifacts() -> &'static Path {
    Path::new("artifacts")
}

fn have_artifacts() -> bool {
    artifacts().join("lenet_weights.bfpw").exists()
}

/// The JAX-trained LeNet must classify the Rust-generated digit set
/// accurately — proving the datagen twins and the .bfpw interchange line
/// up across the language boundary.
#[test]
fn trained_lenet_transfers_across_languages() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let model = ModelId::Lenet.build(32, 1, artifacts());
    let ds = bfp_cnn::data::DigitDataset::generate(100, 31337);
    let logits = forward_batch_ref(&model, &ds.images, ExecMode::Fp32);
    let correct = logits
        .iter()
        .zip(&ds.labels)
        .filter(|(l, &y)| {
            l.data.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 == y
        })
        .count();
    assert!(correct >= 90, "trained lenet only {correct}/100 on rust digits");
}

/// 8-bit BFP must cost (almost) no accuracy on the trained nets — the
/// paper's headline claim, end to end through the Rust engine.
#[test]
fn bfp8_near_lossless_on_trained_nets() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for id in [ModelId::Lenet, ModelId::Cifar10] {
        let model = id.build(32, 1, artifacts());
        let set = eval_set_for(id, &model, 60, 99);
        let drop = drop_for(&model, &set, BfpConfig::paper_default());
        assert!(drop.abs() <= 0.05, "{}: 8-bit drop {drop}", id.name());
    }
}

/// Width monotonicity on a trained net: aggressive narrowing hurts more.
#[test]
fn narrower_widths_hurt_more() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let model = ModelId::Cifar10.build(32, 1, artifacts());
    let set = eval_set_for(ModelId::Cifar10, &model, 60, 5);
    let d3 = drop_for(&model, &set, BfpConfig::new(3, 3));
    let d8 = drop_for(&model, &set, BfpConfig::new(8, 8));
    assert!(d3 >= d8 - 0.02, "3-bit drop {d3} should exceed 8-bit drop {d8}");
}

/// The weight bundle parses and has exactly the LeNet shapes.
#[test]
fn weight_bundle_shapes() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let b = WeightBundle::load(&artifacts().join("lenet_weights.bfpw")).unwrap();
    for (name, shape) in bfp_cnn::models::lenet::expected_shapes() {
        let t = b.tensor(name).unwrap();
        assert_eq!(t.shape, shape, "{name}");
    }
}

/// PJRT runtime: load + execute the standalone BFP GEMM artifact and
/// check it against the Rust BFP GEMM on the same inputs.
#[test]
fn pjrt_bfp_gemm_matches_rust_engine() {
    let path = artifacts().join("bfp_gemm_demo.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: {} not built", path.display());
        return;
    }
    use bfp_cnn::bfp::partition::BlockAxis;
    use bfp_cnn::bfp::{bfp_gemm, BfpFormat, BfpMatrix};

    let rt = bfp_cnn::runtime::PjrtRuntime::cpu().unwrap();
    let art = rt.load_hlo_text(&path).unwrap();

    let mut rng = bfp_cnn::data::Rng::new(17);
    let w = rng.laplacian_vec(4 * 8, 0.3);
    let i = rng.normal_vec(8 * 16, 1.0);
    let outs = art.run_f32(&[(&w, &[4, 8]), (&i, &[8, 16])]).unwrap();
    assert_eq!(outs.len(), 1);

    let wq = BfpMatrix::quantize(&w, 4, 8, BfpFormat::new(8), BlockAxis::PerRow);
    let iq = BfpMatrix::quantize(&i, 8, 16, BfpFormat::new(8), BlockAxis::Whole);
    let rust_out = bfp_gemm(&wq, &iq);
    assert_eq!(outs[0].len(), rust_out.data.len());
    for (a, b) in outs[0].iter().zip(&rust_out.data) {
        assert!(
            (a - b).abs() <= a.abs().max(b.abs()) * 1e-5 + 1e-6,
            "pallas artifact {a} vs rust engine {b}"
        );
    }
}

/// PJRT LeNet artifact agrees with the Rust fp-engine's BFP path on the
/// same batch (full L1=L2=L3 stack consistency).
#[test]
fn pjrt_lenet_artifact_matches_rust_bfp_path() {
    let hlo = artifacts().join("lenet_fwd_b8.hlo.txt");
    if !hlo.exists() {
        eprintln!("skipping: {} not built", hlo.display());
        return;
    }
    let rt = bfp_cnn::runtime::PjrtRuntime::cpu().unwrap();
    let art = rt.load_hlo_text(&hlo).unwrap();
    let weights = WeightBundle::load(&artifacts().join("lenet_weights.bfpw")).unwrap();

    // weight args in manifest order
    let manifest = std::fs::read_to_string(artifacts().join("lenet_fwd_b8.args.txt")).unwrap();
    let mut args_owned: Vec<(Vec<f32>, Vec<i64>)> = Vec::new();
    for line in manifest.lines() {
        let mut parts = line.split_whitespace();
        let name = parts.next().unwrap();
        if name == "__input__" {
            continue;
        }
        let shape: Vec<i64> = parts.map(|d| d.parse().unwrap()).collect();
        args_owned.push((weights.vec(name).unwrap(), shape));
    }

    let ds = bfp_cnn::data::DigitDataset::generate(8, 4242);
    let mut flat = Vec::new();
    for img in &ds.images {
        flat.extend_from_slice(&img.data);
    }
    let shape = [8i64, 1, 28, 28];
    let mut args: Vec<(&[f32], &[i64])> =
        args_owned.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
    args.push((&flat, &shape));
    let outs = art.run_f32(&args).unwrap();
    let pjrt_logits = &outs[0];

    let model = ModelId::Lenet.build(32, 1, artifacts());
    let rust_logits = forward_batch_ref(&model, &ds.images, ExecMode::Bfp(BfpConfig::paper_default()));

    for (b, rust) in rust_logits.iter().enumerate() {
        for (c, &rv) in rust.data.iter().enumerate() {
            let pv = pjrt_logits[b * 10 + c];
            assert!(
                (pv - rv).abs() <= rv.abs().max(1.0) * 5e-3,
                "batch {b} class {c}: pjrt {pv} vs rust {rv}"
            );
        }
    }
}

/// Whole-harness smoke: every table/figure driver runs end to end on a
/// tiny configuration.
#[test]
fn all_harnesses_smoke() {
    use bfp_cnn::harness::{fig3, table1, table2, table3, table4};
    assert_eq!(table1::run(8, 8).len(), 2);
    let t2 = table2::run(32, 2, 1, artifacts());
    assert_eq!(t2.rows.len(), 5); // eq2/eq4 × {L=8, L=6} + fp32 row
    let t3 = table3::run_model(ModelId::Lenet, 32, 4, 1, artifacts());
    assert_eq!(t3.rows.len(), 4);
    let (t4, dev) = table4::run(32, 1, 1, artifacts());
    assert!(t4.rows.len() > 40);
    assert!(dev.is_finite());
    let f3 = fig3::run(32, 1, 1, artifacts());
    assert_eq!(f3.rows.len(), 4);
}

/// Serving pipeline: batched BFP inference through the coordinator hits
/// the same accuracy as direct engine calls.
#[test]
fn coordinator_matches_direct_engine() {
    use bfp_cnn::coordinator::server::{InferenceServer, RustBackend, ServerConfig};
    let model = ModelId::Lenet.build(32, 1, artifacts());
    let ds = bfp_cnn::data::DigitDataset::generate(16, 909);
    let direct = forward_batch_ref(&model, &ds.images, ExecMode::Bfp(BfpConfig::paper_default()));

    let model2 = ModelId::Lenet.build(32, 1, artifacts());
    let mut server = InferenceServer::start(
        Box::new(RustBackend { model: model2, mode: ExecMode::Bfp(BfpConfig::paper_default()) }),
        ServerConfig::default(),
    );
    let pending: Vec<_> = ds.images.iter().map(|i| server.submit(i.clone())).collect();
    for (rx, want) in pending.into_iter().zip(&direct) {
        let got = rx.recv().unwrap().logits;
        assert_eq!(got.data, want.data, "served logits must match direct engine");
    }
    server.shutdown();
}
