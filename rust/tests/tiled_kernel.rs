//! Bit-exactness of the tiled BFP GEMM microkernel and the fused
//! im2col→quantize→pack activation pipeline against the retained naive
//! reference (`bfp::gemm`), per the §3.4 exactness argument:
//!
//! * tail shapes — M/K/N that are *not* multiples of MR/NR/KC (and a
//!   shape crossing the MC/NC task-block boundaries) — across all four
//!   partition schemes, widths spanning the f32-lane/i32/i64 dispatch
//!   boundaries, at 1/2/4 threads;
//! * the fused pipeline's packed mantissas and block exponents equal
//!   `im2col → BfpMatrix::quantize → pack_matrix` exactly, including
//!   strided geometries whose receptive fields skip input pixels;
//! * the prepared/`WeightCache` serving path stays bit-identical to the
//!   unprepared executor on every lane, scheme and thread count.

use bfp_cnn::bfp::kernel::{self, ActPanels, WeightPanels, KC, MC, MR, NC, NR};
use bfp_cnn::bfp::partition::PartitionScheme;
use bfp_cnn::bfp::{bfp_gemm, BfpFormat, BfpMatrix};
use bfp_cnn::data::Rng;
use bfp_cnn::models::Model;
use bfp_cnn::nn::prepared::PreparedModel;
use bfp_cnn::nn::{BfpExec, Block};
use bfp_cnn::quant::{BfpConfig, LayerSchedule};
use bfp_cnn::runtime::pool;
use bfp_cnn::tensor::{im2col, Conv2dGeometry, Tensor};

const SCHEMES: [PartitionScheme; 4] =
    [PartitionScheme::Eq2, PartitionScheme::Eq3, PartitionScheme::Eq4, PartitionScheme::Eq5];

fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: element {i}: {x} vs {y}");
    }
}

/// Shapes exercising every tail case of the MR/NR register tile, the
/// KC segmentation and the MC/NC task blocking.
fn tail_shapes() -> Vec<(usize, usize, usize)> {
    assert_eq!((MR, NR, MC, NC), (4, 8, 64, 256), "shape list assumes these tile constants");
    vec![
        (1, 1, 1),        // degenerate minimum
        (3, 5, 7),        // everything below one register tile
        (5, 67, 9),       // M, N tails; K just past the 10-bit chunk (64)
        (13, 70, 33),     // mixed tails
        (4, 8, 16),       // exact multiples (no tails at all)
        (65, 130, 257),   // crosses the MC row-block and NC col-block boundaries
        (2, KC + 3, 11),  // K crosses the KC segment boundary
    ]
}

/// Tiled output == naive output, bit for bit, across the shape × width
/// × scheme × thread matrix. Widths pin each accumulator lane:
/// 4/8 → f32 single-chunk, 10 → f32 multi-chunk once K > 64,
/// 12 → i32, 16 → i64.
#[test]
fn tiled_gemm_bit_identical_to_naive_reference() {
    let mut rng = Rng::new(0x71D5);
    for (m, k, n) in tail_shapes() {
        let w = rng.normal_vec(m * k, 1.2);
        let i = rng.normal_vec(k * n, 2.5);
        for bits in [4u32, 8, 10, 12, 16] {
            let fmt = BfpFormat::new(bits);
            for scheme in SCHEMES {
                let wq = BfpMatrix::quantize(&w, m, k, fmt, scheme.w_axis());
                let iq = BfpMatrix::quantize(&i, k, n, fmt, scheme.i_axis());
                let naive = pool::with_threads(1, || bfp_gemm(&wq, &iq).data);
                for t in [1usize, 2, 4] {
                    let mut tiled = vec![0f32; m * n];
                    pool::with_threads(t, || kernel::bfp_gemm_tiled(&wq, &iq, &mut tiled));
                    assert_bits_eq(&naive, &tiled, &format!("{m}x{k}x{n} L={bits} {scheme:?} t={t}"));
                }
            }
        }
    }
}

/// Zero rows/columns/matrices keep their exact +0.0 semantics through
/// the tiled rescale (the naive kernel's zero-exponent floors).
#[test]
fn tiled_gemm_zero_blocks_match_naive() {
    let fmt = BfpFormat::new(8);
    let mut rng = Rng::new(0x5EED);
    let (m, k, n) = (6, 10, 13);
    // one all-zero weight row, one all-zero input column
    let mut w = rng.normal_vec(m * k, 1.0);
    for kk in 0..k {
        w[2 * k + kk] = 0.0;
    }
    let mut i = rng.normal_vec(k * n, 1.0);
    for kk in 0..k {
        i[kk * n + 5] = 0.0;
    }
    for scheme in SCHEMES {
        let wq = BfpMatrix::quantize(&w, m, k, fmt, scheme.w_axis());
        let iq = BfpMatrix::quantize(&i, k, n, fmt, scheme.i_axis());
        let naive = bfp_gemm(&wq, &iq).data;
        let mut tiled = vec![0f32; m * n];
        kernel::bfp_gemm_tiled(&wq, &iq, &mut tiled);
        assert_bits_eq(&naive, &tiled, &format!("zero blocks {scheme:?}"));
    }
    // fully zero weight matrix
    let zeros = vec![0.0; m * k];
    let wq = BfpMatrix::quantize(&zeros, m, k, fmt, PartitionScheme::Eq4.w_axis());
    let iq = BfpMatrix::quantize(&i, k, n, fmt, PartitionScheme::Eq4.i_axis());
    let mut tiled = vec![1f32; m * n];
    kernel::bfp_gemm_tiled(&wq, &iq, &mut tiled);
    assert!(tiled.iter().all(|&x| x == 0.0 && x.is_sign_positive()));
}

/// The fused pipeline must emit exactly the exponents and packed
/// mantissas of the unfused path (full im2col → `BfpMatrix::quantize` →
/// `pack_matrix`), for both activation block axes, both panel
/// representations, strided/padded geometries, and NC-boundary N.
#[test]
fn fused_pipeline_equals_unfused_quantize_pack() {
    let mut rng = Rng::new(0xF05ED);
    for (c, h, w, kh, kw, stride, pad) in [
        (3usize, 8, 8, 3, 3, 1, 1),   // n = 64
        (2, 9, 7, 3, 3, 2, 1),        // strided, odd spatial
        (1, 10, 10, 2, 2, 3, 0),      // stride > kernel: uncovered pixels
        (4, 16, 16, 3, 3, 1, 1),      // n = 256 = NC exactly
        (3, 17, 15, 3, 3, 1, 1),      // n = 255: NC tail one short
    ] {
        let img = rng.normal_vec(c * h * w, 1.7);
        let geo = Conv2dGeometry { in_channels: c, in_h: h, in_w: w, kernel_h: kh, kernel_w: kw, stride, padding: pad };
        let (k, n) = (geo.k(), geo.n());
        for (bits, i_bits) in [(8u32, 8u32), (12, 12), (16, 14)] {
            let fmt = BfpFormat::new(i_bits);
            let lane = kernel::select_lane(BfpFormat::new(bits).frac_bits(), fmt.frac_bits(), k);
            for axis in [PartitionScheme::Eq4.i_axis(), PartitionScheme::Eq3.i_axis()] {
                // unfused reference
                let mut col = vec![0f32; k * n];
                im2col(&img, &geo, &mut col);
                let iq = BfpMatrix::quantize(&col, k, n, fmt, axis);
                let mut want = ActPanels::new();
                want.pack_matrix(&iq, lane);
                // fused
                let mut got = ActPanels::new();
                let mut tile = Vec::new();
                got.pack_im2col(&img, &geo, fmt, axis, lane, &mut tile);
                let ctx = format!("{c}ch {h}x{w} k{kh} s{stride} p{pad} L={i_bits} {axis:?}");
                assert_eq!(got.exponents(), want.exponents(), "{ctx}: exponents");
                assert_eq!(got.f32_panels(), want.f32_panels(), "{ctx}: f32 panels");
                assert_eq!(got.i32_panels(), want.i32_panels(), "{ctx}: i32 panels");
                assert!(tile.len() <= k * NC, "{ctx}: staging tile exceeded K×NC");
            }
        }
    }
}

fn tail_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    // out_channels 5 and 3 (not multiples of MR), spatial sizes giving
    // odd GEMM N, plus a strided conv
    Model {
        name: "tail".into(),
        graph: Block::seq(vec![
            Block::Conv(bfp_cnn::models::init::conv2d("c1", 5, 2, 3, 3, 1, 1, &mut rng)),
            Block::ReLU,
            Block::Conv(bfp_cnn::models::init::conv2d("c2", 3, 5, 3, 3, 2, 0, &mut rng)),
            Block::Flatten,
        ]),
        input_shape: vec![2, 11, 9],
        num_classes: 0,
    }
}

/// Prepared serving (WeightCache pre-packed panels + fused workspace
/// pipeline) == unprepared `BfpExec`, bit for bit, on every lane,
/// scheme and thread count — including after schedule hot-swaps across
/// lanes.
#[test]
fn prepared_path_bit_identical_across_lanes_schemes_threads() {
    let model = tail_model(42);
    let mut rng = Rng::new(7);
    let img = Tensor::from_vec(rng.normal_vec(2 * 11 * 9, 1.5), &[2, 11, 9]);
    let configs = [
        BfpConfig::new(8, 8),                                    // f32 lane
        BfpConfig::new(12, 12),                                  // i32 lane
        BfpConfig::new(16, 16),                                  // i64 lane
        BfpConfig::new(8, 8).with_scheme(PartitionScheme::Eq2),
        BfpConfig::new(8, 8).with_scheme(PartitionScheme::Eq3),  // PerCol activations
        BfpConfig::new(8, 8).with_scheme(PartitionScheme::Eq5),
    ];
    for cfg in configs {
        let schedule = LayerSchedule::uniform(cfg);
        let want = model.graph.execute(img.clone(), &mut BfpExec::with_schedule(schedule.clone()));
        let prepared = PreparedModel::new(model.clone(), schedule);
        for t in [1usize, 2, 4] {
            let got = pool::with_threads(t, || prepared.forward(&img));
            assert_bits_eq(&want.data, &got.data, &format!("cfg {cfg:?} t={t}"));
        }
    }
    // schedule hot-swap across accumulator lanes through one cache
    let mut prepared = PreparedModel::new(model.clone(), LayerSchedule::uniform(configs[0]));
    for cfg in [configs[2], configs[1], configs[0]] {
        let schedule = LayerSchedule::uniform(cfg);
        prepared.set_schedule(schedule.clone());
        let want = model.graph.execute(img.clone(), &mut BfpExec::with_schedule(schedule));
        let got = prepared.forward(&img);
        assert_bits_eq(&want.data, &got.data, &format!("after swap to {cfg:?}"));
    }
    let (_, hits, _) = prepared.cache_stats();
    assert!(hits >= 2, "swapping back must hit the weight cache");
}

/// Mixed per-layer schedule where the two convs land on *different*
/// accumulator lanes at once (one cache entry carries each packing).
#[test]
fn mixed_lane_schedule_bit_identical() {
    let model = tail_model(9);
    let mut rng = Rng::new(23);
    let img = Tensor::from_vec(rng.normal_vec(2 * 11 * 9, 2.0), &[2, 11, 9]);
    let schedule = LayerSchedule::uniform(BfpConfig::new(8, 8)).with_layer("c2", BfpConfig::new(16, 16));
    let want = model.graph.execute(img.clone(), &mut BfpExec::with_schedule(schedule.clone()));
    let prepared = PreparedModel::new(model, schedule);
    for t in [1usize, 2, 4] {
        let got = pool::with_threads(t, || prepared.forward(&img));
        assert_bits_eq(&want.data, &got.data, &format!("mixed lanes t={t}"));
    }
}

/// `WeightPanels` packed for the wrong lane must be rejected loudly,
/// never silently mis-multiplied.
#[test]
#[should_panic(expected = "lane")]
fn wrong_lane_panels_are_rejected() {
    let fmt = BfpFormat::new(8); // f32 lane
    let wq = BfpMatrix::quantize(&[1.0; 12], 3, 4, fmt, PartitionScheme::Eq4.w_axis());
    let iq = BfpMatrix::quantize(&[1.0; 8], 4, 2, fmt, PartitionScheme::Eq4.i_axis());
    let lane = kernel::select_lane(wq.frac_bits, iq.frac_bits, 4);
    let mut acts = ActPanels::new();
    acts.pack_matrix(&iq, lane);
    let panels = kernel::pack_weights_i32(&wq); // wrong: f32 lane selected
    let mut out = vec![0f32; 6];
    kernel::gemm_tiled(&wq, WeightPanels::Int(&panels), &acts, &mut out);
}
