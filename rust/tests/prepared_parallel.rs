//! Determinism and cache-coherence properties of the prepared-model
//! engine and the panel-parallel kernels:
//!
//! * panel-parallel GEMM output is bit-identical to the serial reference
//!   across all four partition schemes and every accumulator lane, at
//!   thread counts {1, 2, 4};
//! * a `PreparedModel` forward equals the unprepared `BfpExec` forward
//!   bit-for-bit, including after schedule swaps invalidate cached
//!   weights;
//! * `Workspace` reuse across differently-shaped layers leaves no stale
//!   data.
//!
//! proptest is unavailable in the offline image, so properties run over
//! the library's deterministic `Rng` across randomized shapes/widths.

use bfp_cnn::bfp::gemm::f32_gemm;
use bfp_cnn::bfp::partition::PartitionScheme;
use bfp_cnn::bfp::{bfp_gemm, BfpFormat, BfpMatrix};
use bfp_cnn::coordinator::engine::{forward_batch_ref, ExecMode};
use bfp_cnn::data::Rng;
use bfp_cnn::models::{Model, ModelId};
use bfp_cnn::nn::prepared::{PreparedModel, Workspace};
use bfp_cnn::nn::{Block, Conv2d};
use bfp_cnn::quant::{BfpConfig, LayerSchedule};
use bfp_cnn::runtime::pool;
use bfp_cnn::tensor::Tensor;
use std::path::Path;

const SCHEMES: [PartitionScheme; 4] =
    [PartitionScheme::Eq2, PartitionScheme::Eq3, PartitionScheme::Eq4, PartitionScheme::Eq5];

fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: element {i}: {x} vs {y}");
    }
}

/// Every lane (f32 mantissa single- and multi-chunk, i32, i64), every
/// scheme, thread counts {1, 2, 4}: parallel output must equal serial
/// bit-for-bit.
#[test]
fn parallel_gemm_bit_identical_to_serial() {
    let mut rng = Rng::new(0x9A7A11E1);
    // widths chosen to pin each lane: 8 → f32 single-chunk, 10 → f32
    // multi-chunk once K > 64, 12 → i32, 16 → i64. Shapes sit above
    // pool::MIN_PARALLEL_WORK MACs so the panel-parallel path actually
    // runs (m·k·n ≥ 24·65·96 > 2^17).
    for (case, &bits) in [8u32, 10, 12, 16].iter().cycle().take(12).enumerate() {
        let m = 24 + rng.below(16);
        let k = 65 + rng.below(31);
        let n = 96 + rng.below(32);
        assert!(m * k * n >= pool::MIN_PARALLEL_WORK);
        let w = rng.normal_vec(m * k, 1.0);
        let i = rng.normal_vec(k * n, 2.0);
        for scheme in SCHEMES {
            let wq = BfpMatrix::quantize(&w, m, k, BfpFormat::new(bits), scheme.w_axis());
            let iq = BfpMatrix::quantize(&i, k, n, BfpFormat::new(bits), scheme.i_axis());
            let serial = pool::with_threads(1, || bfp_gemm(&wq, &iq).data);
            for t in [2usize, 4] {
                let par = pool::with_threads(t, || bfp_gemm(&wq, &iq).data);
                assert_bits_eq(
                    &serial,
                    &par,
                    &format!("case {case} ({m}x{k}x{n}, L={bits}, {scheme:?}, t={t})"),
                );
            }
        }
        // and the f32 reference GEMM
        let mut serial = vec![0f32; m * n];
        pool::with_threads(1, || f32_gemm(&w, &i, m, k, n, &mut serial));
        for t in [2usize, 4] {
            let mut par = vec![0f32; m * n];
            pool::with_threads(t, || f32_gemm(&w, &i, m, k, n, &mut par));
            assert_bits_eq(&serial, &par, &format!("f32_gemm case {case} t={t}"));
        }
    }
}

/// PreparedModel output == unprepared BfpExec output, bit for bit, for
/// uniform and mixed schedules, before and after schedule swaps, at
/// every thread count.
#[test]
fn prepared_model_matches_bfp_exec_bit_for_bit() {
    let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
    let images = bfp_cnn::data::DigitDataset::generate(3, 17).images;
    let uniform = BfpConfig::paper_default();
    let mixed = LayerSchedule::uniform(BfpConfig::new(6, 6)).with_layer("conv1", BfpConfig::new(9, 9));

    let want_uniform = forward_batch_ref(&model, &images, ExecMode::Bfp(uniform));
    let want_mixed = forward_batch_ref(&model, &images, ExecMode::Mixed(mixed.clone()));

    let mut prepared = PreparedModel::new(model, LayerSchedule::uniform(uniform));
    for t in [1usize, 2, 4] {
        let got = pool::with_threads(t, || prepared.forward_batch(images.clone()));
        for (a, b) in want_uniform.iter().zip(&got) {
            assert_bits_eq(&a.data, &b.data, &format!("uniform t={t}"));
        }
    }

    // schedule swap: cached weights for changed layers must be replaced
    prepared.set_schedule(mixed);
    let got = prepared.forward_batch(images.clone());
    for (a, b) in want_mixed.iter().zip(&got) {
        assert_bits_eq(&a.data, &b.data, "after swap to mixed");
    }

    // swap back: served from cache, still bit-identical
    prepared.set_schedule(LayerSchedule::uniform(uniform));
    let got = prepared.forward_batch(images.clone());
    for (a, b) in want_uniform.iter().zip(&got) {
        assert_bits_eq(&a.data, &b.data, "after swap back to uniform");
    }
    let (_, hits, misses) = prepared.cache_stats();
    assert!(hits >= 2, "swap back must hit the cache (hits={hits})");
    // lenet has 2 convs: uniform (2 misses) + mixed (2 misses), then all hits
    assert_eq!(misses, 4, "unexpected quantization count");
}

/// One Workspace reused across two models with very different layer
/// shapes (and interleaved directions) must reproduce fresh-arena
/// results exactly — no stale im2col / mantissa state may leak.
#[test]
fn workspace_reuse_across_shapes_leaves_no_stale_data() {
    let mut rng = Rng::new(0x57A1E);
    let big_conv = Conv2d::new(
        "big",
        Tensor::from_vec(rng.laplacian_vec(8 * 4 * 9, 0.2), &[8, 4, 3, 3]),
        rng.normal_vec(8, 0.1),
        1,
        1,
    );
    let small_conv = Conv2d::new(
        "small",
        Tensor::from_vec(rng.laplacian_vec(3 * 2 * 9, 0.3), &[3, 2, 3, 3]),
        vec![],
        2,
        0,
    );
    let big = Model {
        name: "big".into(),
        graph: Block::seq(vec![Block::Conv(big_conv), Block::ReLU]),
        input_shape: vec![4, 16, 16],
        num_classes: 0,
    };
    let small = Model {
        name: "small".into(),
        graph: Block::seq(vec![Block::Conv(small_conv)]),
        input_shape: vec![2, 7, 7],
        num_classes: 0,
    };
    let img_big = Tensor::from_vec(rng.normal_vec(4 * 16 * 16, 1.0), &[4, 16, 16]);
    let img_small = Tensor::from_vec(rng.normal_vec(2 * 7 * 7, 1.0), &[2, 7, 7]);

    let pm_big = PreparedModel::new(big, LayerSchedule::uniform(BfpConfig::paper_default()));
    let pm_small = PreparedModel::new(small, LayerSchedule::uniform(BfpConfig::new(6, 10)));

    let fresh_big = pm_big.forward_with(&img_big, &mut Workspace::new());
    let fresh_small = pm_small.forward_with(&img_small, &mut Workspace::new());

    let mut shared = Workspace::new();
    // big grows the arena; small must not read the leftovers, and a
    // second big pass must be unaffected by the small pass in between
    let a = pm_big.forward_with(&img_big, &mut shared);
    let b = pm_small.forward_with(&img_small, &mut shared);
    let c = pm_big.forward_with(&img_big, &mut shared);
    assert_bits_eq(&fresh_big.data, &a.data, "big through fresh vs shared");
    assert_bits_eq(&fresh_small.data, &b.data, "small after big");
    assert_bits_eq(&fresh_big.data, &c.data, "big after small");
    assert!(shared.col_capacity() >= 4 * 9 * 16 * 16, "arena did not grow to the big layer");
}

/// The engine's image-parallel forward_batch and the prepared batch path
/// agree with each other and across thread counts.
#[test]
fn batch_paths_agree_across_thread_counts() {
    let model = ModelId::Lenet.build(32, 1, Path::new("/nonexistent"));
    let images = bfp_cnn::data::DigitDataset::generate(6, 5).images;
    let cfg = BfpConfig::paper_default();
    let reference =
        pool::with_threads(1, || forward_batch_ref(&model, &images, ExecMode::Bfp(cfg)));
    let prepared = PreparedModel::new(model.clone(), LayerSchedule::uniform(cfg));
    for t in [1usize, 2, 4] {
        let engine = pool::with_threads(t, || forward_batch_ref(&model, &images, ExecMode::Bfp(cfg)));
        let warm = pool::with_threads(t, || prepared.forward_batch(images.clone()));
        for ((a, b), c) in reference.iter().zip(&engine).zip(&warm) {
            assert_bits_eq(&a.data, &b.data, &format!("engine t={t}"));
            assert_bits_eq(&a.data, &c.data, &format!("prepared t={t}"));
        }
    }
}
