//! Integration tests for the QoS precision router (ISSUE 3 + ISSUE 5
//! acceptance):
//!
//! (a) every lane serves logits bit-identical to a standalone
//!     [`PreparedModel`] on the same plan;
//! (b) classes are never mixed within a batch;
//! (c) a forced NSR-bound violation hot-swaps the lane to the next-safer
//!     plan without dropping in-flight requests;
//! (d) per-class metrics (p50/p99, downgrade count) are reported, and
//!     synthetic overload downgrades non-Gold traffic to cheaper lanes;
//! (e) the per-lane multi-worker executor serves per-request logits
//!     bit-identical to the single-worker reference scheduler, preserves
//!     class purity and never-downgrade-gold under work-stealing, and a
//!     panicking executor surfaces as a typed per-request error plus a
//!     supervisor respawn — never a client-side panic;
//! (f) a lane that exhausts its restart budget retires, its traffic
//!     re-routes to the adjacent safer lane, and the final report still
//!     carries the complete pre-fault per-class/per-tenant/per-lane
//!     accounting (PR 7 regression).
//!
//! Unless a test pins `workers` explicitly, the suite honours
//! `BFP_QOS_WORKERS` — CI runs it under both schedulers (and once more
//! with `BFP_FAULTS` arming benign delay injection).

use bfp_cnn::coordinator::batcher::BatchPolicy;
use bfp_cnn::coordinator::{
    LaneSet, LaneSpec, LaneStep, QosClass, QosConfig, QosErrorKind, QosResponse, QosServer,
    ShedPolicy, WorkerMode,
};
use bfp_cnn::models::ModelId;
use bfp_cnn::nn::PreparedModel;
use bfp_cnn::quant::{BfpConfig, LayerSchedule};
use bfp_cnn::telemetry::MonitorConfig;
use bfp_cnn::Tensor;
use std::collections::HashMap;
use std::path::Path;
use std::time::Duration;

fn lenet() -> bfp_cnn::models::Model {
    ModelId::Lenet.build(32, 1, Path::new("/nonexistent"))
}

fn images(n: usize, seed: u64) -> Vec<Tensor> {
    bfp_cnn::data::DigitDataset::generate(n, seed).images
}

fn demo_lane_set() -> LaneSet {
    LaneSet::from_steps(
        LaneStep::uniform(9, 9),
        LaneStep::uniform(7, 7),
        LaneStep::uniform(5, 5),
        Some(LaneStep::uniform(4, 4)),
    )
}

/// The uniform width pair each lane of [`demo_lane_set`] operates.
fn lane_widths(lane: &str) -> BfpConfig {
    match lane {
        "gold" => BfpConfig::new(9, 9),
        "standard" => BfpConfig::new(7, 7),
        "economy" => BfpConfig::new(5, 5),
        "shed" => BfpConfig::new(4, 4),
        other => panic!("unknown lane {other}"),
    }
}

/// Telemetry off, shedding off: pure routing.
fn quiet_config() -> QosConfig {
    QosConfig {
        policy: BatchPolicy { max_batch: 4, linger: Duration::from_millis(2) },
        shed: ShedPolicy { enabled: false, queue_pressure: 0 },
        monitor: MonitorConfig { sample_every: 0, ..Default::default() },
        ..QosConfig::default()
    }
}

/// (a) + (b) + (d): a three-class mixed workload ends with bit-identical
/// logits per lane, class-pure batches, and populated per-class metrics.
#[test]
fn mixed_workload_is_bit_identical_class_pure_and_metered() {
    let model = lenet();
    let set = demo_lane_set();
    let mut server = QosServer::start(model.clone(), &set, quiet_config());

    let imgs = images(18, 42);
    let classes: Vec<QosClass> = (0..imgs.len()).map(|i| QosClass::ALL[i % 3]).collect();
    let pending: Vec<_> = imgs
        .iter()
        .zip(&classes)
        .map(|(img, &c)| server.submit(c, img.clone()).unwrap())
        .collect();
    let responses: Vec<QosResponse> =
        pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let report = server.shutdown();

    // (a) bit-identical to a standalone PreparedModel on the same plan
    for class in QosClass::ALL {
        let reference =
            PreparedModel::new(model.clone(), LayerSchedule::uniform(lane_widths(class.name())));
        for (i, resp) in responses.iter().enumerate() {
            if classes[i] != class {
                continue;
            }
            assert_eq!(resp.served_by, class.name(), "no downgrades with shedding off");
            let want = reference.forward(&imgs[i]);
            assert_eq!(want.shape, resp.logits.shape);
            for (a, b) in want.data.iter().zip(&resp.logits.data) {
                let lane = class.name();
                assert_eq!(a.to_bits(), b.to_bits(), "lane {lane} diverged from its plan");
            }
        }
    }

    // (b) responses sharing a batch_seq all carry the same class
    let mut by_batch: HashMap<u64, Vec<&QosResponse>> = HashMap::new();
    for r in &responses {
        by_batch.entry(r.batch_seq).or_default().push(r);
    }
    for (seq, members) in &by_batch {
        let first = members[0].class;
        assert!(
            members.iter().all(|r| r.class == first),
            "batch {seq} mixed classes: {:?}",
            members.iter().map(|r| r.class).collect::<Vec<_>>()
        );
        assert!(members.iter().all(|r| r.batch_size >= members.len()));
        // batch-consistent metadata: a batch executes on exactly one
        // lane under one precision step
        assert!(
            members
                .iter()
                .all(|r| r.served_by == members[0].served_by
                    && r.lane_plan == members[0].lane_plan),
            "batch {seq} split across lanes"
        );
    }

    // (d) per-class metrics are populated
    assert_eq!(report.metrics.total_requests, 18);
    for class in QosClass::ALL {
        let cm = report.metrics.class(class.name()).expect("per-class metrics");
        assert_eq!(cm.requests, 6);
        assert_eq!(cm.downgrades, 0);
        assert!(cm.latency_p(50.0) > 0.0);
        assert!(cm.latency_p(99.0) >= cm.latency_p(50.0));
    }
    assert_eq!(report.lanes.len(), 4, "three class lanes + shed lane");
    assert!(!report.worker_panic);
}

/// Deadline-miss flags derive from one completion instant per batch
/// (the per-response skew regression, end-to-end): requests submitted
/// with an already-expired deadline must *all* come back flagged
/// missed, in every worker mode, and the per-class accounting must
/// agree response-for-response. (The exact single-instant property is
/// pinned deterministically by `batch_responses_share_one_completion_
/// instant` in `coordinator::qos`; this drives the same path through
/// the public API.)
#[test]
fn pre_expired_deadlines_are_uniformly_missed() {
    for workers in [WorkerMode::Single, WorkerMode::PerLane { steal: true }] {
        let config = QosConfig { workers, ..quiet_config() };
        let mut server = QosServer::start(lenet(), &demo_lane_set(), config);
        let imgs = images(8, 23);
        let pending: Vec<_> = imgs
            .iter()
            .map(|img| {
                server
                    .submit_with_deadline(QosClass::Standard, img.clone(), Duration::ZERO)
                    .unwrap()
            })
            .collect();
        let responses: Vec<QosResponse> =
            pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        let report = server.shutdown();
        assert!(
            responses.iter().all(|r| r.deadline_missed),
            "an expired deadline must be flagged missed ({})",
            workers.name()
        );
        let cm = report.metrics.class("standard").expect("standard metrics");
        assert_eq!(cm.deadline_misses, 8, "accounting disagrees with flags ({})", workers.name());
    }
}

/// (e) the acceptance gate for the multi-worker executor: the same
/// mixed-class stream through the single-worker reference scheduler and
/// the per-lane executor fabric (stealing enabled) produces
/// bit-identical per-request logits, identical serving lanes, and
/// class-pure batches in both runs.
#[test]
fn per_lane_executor_is_bit_identical_to_the_reference_scheduler() {
    let model = lenet();
    let set = demo_lane_set();
    let imgs = images(15, 77);
    let classes: Vec<QosClass> = (0..imgs.len()).map(|i| QosClass::ALL[i % 3]).collect();

    let run = |workers: WorkerMode| -> Vec<QosResponse> {
        let config = QosConfig { workers, ..quiet_config() };
        let mut server = QosServer::start(model.clone(), &set, config);
        let pending: Vec<_> = imgs
            .iter()
            .zip(&classes)
            .map(|(img, &c)| server.submit(c, img.clone()).unwrap())
            .collect();
        let responses = pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        let report = server.shutdown();
        assert!(!report.worker_panic);
        responses
    };

    let single = run(WorkerMode::Single);
    let per_lane = run(WorkerMode::PerLane { steal: true });
    assert_eq!(single.len(), per_lane.len());
    for (i, (s, p)) in single.iter().zip(&per_lane).enumerate() {
        assert_eq!(s.id, p.id, "submission order must define response identity");
        assert_eq!(s.served_by, p.served_by, "request {i} routed differently");
        assert_eq!(s.lane_plan, p.lane_plan);
        assert_eq!(s.logits.shape, p.logits.shape);
        for (a, b) in s.logits.data.iter().zip(&p.logits.data) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "request {i}: per-lane executor diverged from the reference scheduler"
            );
        }
    }
    // class purity holds under concurrent executors too
    let mut by_batch: HashMap<u64, Vec<&QosResponse>> = HashMap::new();
    for r in &per_lane {
        by_batch.entry(r.batch_seq).or_default().push(r);
    }
    for (seq, members) in &by_batch {
        assert!(
            members.iter().all(|r| r.class == members[0].class),
            "per-lane batch {seq} mixed classes"
        );
    }
}

/// (e) work-stealing: a standard-heavy burst with an idle economy
/// executor moves home-lane standard batches exactly one lane cheaper
/// (recorded as downgrades, served bit-identical to the economy plan),
/// while gold is never stolen or downgraded and batches stay class-pure.
#[test]
fn work_stealing_moves_batches_one_lane_cheaper_and_never_gold() {
    let model = lenet();
    let set = demo_lane_set();
    let config = QosConfig {
        policy: BatchPolicy { max_batch: 1, linger: Duration::from_millis(1) },
        // stealing obeys the shed switch; a huge pressure threshold
        // keeps the dispatcher from downgrading, so every downgrade
        // observed here came from an idle executor stealing
        shed: ShedPolicy { enabled: true, queue_pressure: usize::MAX },
        monitor: MonitorConfig { sample_every: 0, ..Default::default() },
        workers: WorkerMode::PerLane { steal: true },
        ..QosConfig::default()
    };
    let mut server = QosServer::start(model.clone(), &set, config);
    let imgs = images(36, 11);
    // 1 gold : 8 standard — standard queues deep while economy idles
    let classes: Vec<QosClass> = (0..imgs.len())
        .map(|i| if i % 9 == 0 { QosClass::Gold } else { QosClass::Standard })
        .collect();
    let pending: Vec<_> = imgs
        .iter()
        .zip(&classes)
        .map(|(img, &c)| {
            server.submit_with_deadline(c, img.clone(), Duration::from_secs(5)).unwrap()
        })
        .collect();
    let responses: Vec<QosResponse> =
        pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let report = server.shutdown();
    assert!(!report.worker_panic);
    assert_eq!(responses.len(), 36, "stealing dropped requests");

    let mut stolen = 0usize;
    for (i, r) in responses.iter().enumerate() {
        match r.class {
            QosClass::Gold => {
                assert!(!r.downgraded, "gold request stolen/downgraded");
                assert_eq!(r.served_by, "gold");
            }
            QosClass::Standard => {
                if r.downgraded {
                    stolen += 1;
                    assert_eq!(
                        r.served_by, "economy",
                        "a stolen standard batch must move exactly one lane cheaper"
                    );
                } else {
                    assert_eq!(r.served_by, "standard");
                }
            }
            QosClass::Economy => unreachable!("no economy traffic submitted"),
        }
        // (a) still holds: whatever lane served it, the logits match
        // that lane's plan bit-for-bit
        let reference =
            PreparedModel::new(model.clone(), LayerSchedule::uniform(lane_widths(&r.served_by)));
        let want = reference.forward(&imgs[i]);
        for (a, b) in want.data.iter().zip(&r.logits.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "lane {} diverged from its plan", r.served_by);
        }
    }
    // the economy executor idles while 32 standard batches queue behind
    // a capacity-4 hand-off queue: it wins at least one steal race
    assert!(stolen > 0, "idle economy executor never stole from the busy standard lane");
    // accounting agrees with the response flags
    let std_downgrades = report.metrics.class("standard").map(|c| c.downgrades).unwrap_or(0);
    assert_eq!(std_downgrades, stolen as u64);
}

/// (e) a lane executor that panics must not panic clients: the poisoned
/// request comes back as a typed [`QosErrorKind::ExecutorPanic`] error,
/// the supervisor respawns the lane over the shared weight cache, and
/// every lane — the respawned one included — keeps serving and lands in
/// the final report with its restart accounted.
#[test]
fn panicked_lane_executor_respawns_and_keeps_reporting() {
    for workers in [WorkerMode::Single, WorkerMode::PerLane { steal: false }] {
        let model = lenet();
        let set = demo_lane_set();
        let config = QosConfig {
            policy: BatchPolicy { max_batch: 1, linger: Duration::from_millis(1) },
            shed: ShedPolicy { enabled: false, queue_pressure: 0 },
            monitor: MonitorConfig { sample_every: 0, ..Default::default() },
            workers,
            ..QosConfig::default()
        };
        let mut server = QosServer::start(model, &set, config);
        // healthy traffic on gold first
        let ok = server.infer(QosClass::Gold, images(1, 3).remove(0)).expect("gold serves");
        assert_eq!(ok.served_by, "gold");
        // poison pill: wrong input shape panics the economy executor
        // mid-forward; supervision turns that into a typed error reply
        let poisoned = server.submit(QosClass::Economy, Tensor::zeros(&[1, 2, 2])).unwrap();
        let err = poisoned
            .recv()
            .expect("a supervised panic answers with a typed error, never a hang")
            .expect_err("a poisoned batch cannot produce logits");
        assert_eq!(err.kind, QosErrorKind::ExecutorPanic, "{}: {err:?}", workers.name());
        // the supervisor respawned the lane: economy serves again, and
        // gold was never disturbed — the whole point of lane isolation
        let after = server.infer(QosClass::Economy, images(1, 4).remove(0)).unwrap();
        assert_eq!(after.served_by, "economy", "respawned lane must serve its own class");
        assert!(!after.downgraded);
        let ok2 = server.infer(QosClass::Gold, images(1, 5).remove(0)).expect("gold survives");
        assert_eq!(ok2.served_by, "gold");

        let report = server.shutdown();
        assert!(!report.worker_panic, "the dispatcher itself never panicked");
        let labels: Vec<&str> = report.lanes.iter().map(|l| l.label.as_str()).collect();
        assert!(labels.contains(&"economy"), "respawned lane must report: {labels:?}");
        assert!(labels.contains(&"gold") && labels.contains(&"standard"));
        let economy = report.lanes.iter().find(|l| l.label == "economy").unwrap();
        assert!(economy.restarts >= 1, "restart not accounted: {economy:?}");
        assert!(!economy.retired, "one panic is within the default budget");
        assert!(report.metrics.lane_restarts >= 1);
        assert_eq!(report.metrics.lanes_retired, 0);
        let eco = report.metrics.class("economy").expect("economy metrics survive the panic");
        assert_eq!(eco.failures, 1, "exactly the poisoned request failed ({})", workers.name());
    }
}

/// (f) PR 7 regression: a lane that exhausts a zero restart budget
/// retires, later traffic for its class re-routes to the adjacent safer
/// lane (never into shed), and the *partial* report still carries the
/// complete accounting recorded before the fault — per-class counters,
/// per-tenant rows, and a lane row for the retired lane itself.
#[test]
fn retired_lane_report_keeps_prefault_metrics_complete() {
    for workers in [WorkerMode::Single, WorkerMode::PerLane { steal: false }] {
        let model = lenet();
        let set = demo_lane_set();
        let config = QosConfig {
            policy: BatchPolicy { max_batch: 1, linger: Duration::from_millis(1) },
            shed: ShedPolicy { enabled: false, queue_pressure: 0 },
            monitor: MonitorConfig { sample_every: 0, ..Default::default() },
            workers,
            restart_budget: 0,
            ..QosConfig::default()
        };
        let mut server = QosServer::start(model, &set, config);
        // pre-fault traffic on every class, plus a tenant row recorded
        // the way the TCP front records one
        for class in QosClass::ALL {
            for seed in 0..2 {
                let resp = server.infer(class, images(1, 40 + seed).remove(0)).unwrap();
                assert_eq!(resp.served_by, class.name());
            }
        }
        server.metrics_handle().lock().unwrap().record_tenant("vip", false, false);
        // the fault: one panic against a zero budget retires the lane
        let poisoned = server.submit(QosClass::Economy, Tensor::zeros(&[1, 2, 2])).unwrap();
        let err = poisoned.recv().expect("typed reply").expect_err("poison cannot serve");
        assert_eq!(err.kind, QosErrorKind::ExecutorPanic);
        let retired = (0..200).any(|_| {
            std::thread::sleep(Duration::from_millis(2));
            server.health().iter().any(|l| l.label == "economy" && l.retired)
        });
        assert!(retired, "zero budget must retire the lane ({})", workers.name());
        // economy traffic now re-routes one lane safer — standard, not shed
        let rerouted = server.infer(QosClass::Economy, images(1, 44).remove(0)).unwrap();
        assert_eq!(rerouted.served_by, "standard", "retired traffic moves to the safer lane");

        let report = server.shutdown();
        assert!(!report.worker_panic);
        // the partial report is complete about everything pre-fault
        let labels: Vec<&str> = report.lanes.iter().map(|l| l.label.as_str()).collect();
        for lane in ["gold", "standard", "economy", "shed"] {
            assert!(labels.contains(&lane), "lane {lane} missing from report: {labels:?}");
        }
        let economy = report.lanes.iter().find(|l| l.label == "economy").unwrap();
        assert!(economy.retired, "retirement must be visible in the lane report");
        for class in QosClass::ALL {
            let cm = report.metrics.class(class.name()).expect("pre-fault class metrics");
            assert!(cm.requests >= 2, "{}: pre-fault requests lost ({cm:?})", workers.name());
            assert!(cm.latency_p(50.0) > 0.0, "pre-fault latency histogram lost");
        }
        let eco = report.metrics.class("economy").unwrap();
        assert_eq!(eco.failures, 1, "exactly the poisoned request failed");
        assert!(report.metrics.tenants().iter().any(|t| t.label == "vip"), "tenant row lost");
        assert_eq!(report.metrics.lanes_retired, 1);
    }
}

/// (c) a lane whose measured NSR breaks its (impossibly optimistic)
/// predicted bound hot-swaps to the next-safer frontier step while the
/// workload is in flight — and every request still gets its response.
#[test]
fn forced_nsr_violation_hot_swaps_without_dropping_requests() {
    let model = lenet();
    // economy operates a deliberately noisy 4/4 plan whose claimed bound
    // (200 dB) no BFP execution can meet → first probe violates
    let set = LaneSet::from_steps(
        LaneStep::uniform(9, 9),
        LaneStep::uniform(7, 7),
        LaneStep::new(LayerSchedule::uniform(BfpConfig::new(4, 4)), 200.0, "noisy4/4"),
        None,
    );
    let config = QosConfig {
        policy: BatchPolicy { max_batch: 2, linger: Duration::from_millis(1) },
        shed: ShedPolicy { enabled: false, queue_pressure: 0 },
        monitor: MonitorConfig {
            sample_every: 1,
            min_probes: 1,
            margin_db: 0.0,
            ..Default::default()
        },
        ..QosConfig::default()
    };
    let mut server = QosServer::start(model.clone(), &set, config);
    let imgs = images(12, 7);
    let pending: Vec<_> =
        imgs.iter().map(|img| server.submit(QosClass::Economy, img.clone()).unwrap()).collect();
    let responses: Vec<QosResponse> =
        pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    assert_eq!(responses.len(), 12, "in-flight requests were dropped");
    let report = server.shutdown();

    let economy = report.lanes.iter().find(|l| l.label == "economy").unwrap();
    assert!(economy.swaps >= 1, "violation did not trigger a hot-swap: {economy:?}");
    assert!(economy.ladder_pos >= 1);
    // the lane walked to a safer rung: economy's next-safer step is
    // standard's 7/7 operating point
    assert_eq!(economy.plan, "uniform7/7");

    // post-swap responses are bit-identical to the safer plan
    let safer = PreparedModel::new(model, LayerSchedule::uniform(BfpConfig::new(7, 7)));
    let last = responses.last().unwrap();
    let want = safer.forward(imgs.last().unwrap());
    for (a, b) in want.data.iter().zip(&last.logits.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "post-swap lane is not serving the safer plan");
    }
}

/// (c) the full telemetry round trip: a frontier step whose claimed
/// bound sits *between* the real 4/4 and 8/8 output SNRs forces a
/// demotion off the frontier; a sustained healthy window on the safe
/// rung then re-promotes the lane back ([`MonitorConfig`]'s
/// `promote_min_probes` / `promote_margin_db`), and the next probe on
/// the frontier demotes it again — swaps and promotions both land in
/// the lane report.
#[test]
fn telemetry_demotes_then_promotes_back_to_the_frontier() {
    use bfp_cnn::coordinator::engine::{forward_batch_ref, ExecMode};

    let model = lenet();
    let imgs = images(6, 19);
    let fp32 = forward_batch_ref(&model, &imgs, ExecMode::Fp32);
    // measure the true per-image output SNR of both rungs, exactly as
    // the lane probe does (full-model BFP output vs the f32 reference)
    let snr_for = |cfg: BfpConfig| -> Vec<f64> {
        let prepared = PreparedModel::new(model.clone(), LayerSchedule::uniform(cfg));
        imgs.iter()
            .zip(&fp32)
            .map(|(img, want)| {
                let got = prepared.forward(img);
                let (mut sig, mut err) = (0f64, 0f64);
                for (&x, &y) in want.data.iter().zip(&got.data) {
                    sig += (x as f64) * (x as f64);
                    err += ((y - x) as f64) * ((y - x) as f64);
                }
                bfp_cnn::analysis::snr_db(sig, err)
            })
            .collect()
    };
    let best44 = snr_for(BfpConfig::new(4, 4)).into_iter().fold(f64::NEG_INFINITY, f64::max);
    let worst88 = snr_for(BfpConfig::new(8, 8)).into_iter().fold(f64::INFINITY, f64::min);
    assert!(
        worst88 > best44 + 2.0,
        "4/4 ({best44:.1} dB) and 8/8 ({worst88:.1} dB) must separate cleanly for this test"
    );
    let bound = (best44 + worst88) / 2.0;

    // economy's frontier rung claims `bound`: its real 4/4 SNR misses it
    // (demote) while the safe 8/8 rung clears it (promote target met)
    let set = LaneSet {
        gold: LaneSpec::new(vec![LaneStep::uniform(9, 9)]),
        standard: LaneSpec::new(vec![LaneStep::uniform(7, 7)]),
        economy: LaneSpec::new(vec![
            LaneStep::new(LayerSchedule::uniform(BfpConfig::new(4, 4)), bound, "frontier4/4"),
            LaneStep::new(LayerSchedule::uniform(BfpConfig::new(8, 8)), f64::NAN, "safe8/8"),
        ]),
        shed: None,
    };
    let config = QosConfig {
        policy: BatchPolicy { max_batch: 1, linger: Duration::from_millis(1) },
        shed: ShedPolicy { enabled: false, queue_pressure: 0 },
        monitor: MonitorConfig {
            sample_every: 1,
            min_probes: 1,
            margin_db: 0.0,
            promote_min_probes: 3,
            promote_margin_db: 0.0,
        },
        ..QosConfig::default()
    };
    let mut server = QosServer::start(model, &set, config);
    // serial economy traffic, one probe per request: probe 1 violates on
    // the frontier (demote), probes 2-4 are healthy on the safe rung
    // (promote at the 3rd), probe 5 violates on the frontier again
    for img in &imgs {
        server.infer(QosClass::Economy, img.clone()).expect("economy serves");
    }
    let report = server.shutdown();
    let economy = report.lanes.iter().find(|l| l.label == "economy").unwrap();
    assert!(economy.swaps >= 2, "expected demote → promote → demote: {economy:?}");
    assert!(economy.promotions >= 1, "healthy window never re-promoted: {economy:?}");
    assert!(
        economy.swaps > economy.promotions,
        "every promotion is preceded by a demotion: {economy:?}"
    );
}

/// (d) synthetic overload: with a tiny pressure threshold, queued
/// non-Gold traffic downgrades to cheaper lanes and the accounting shows
/// it — while Gold is never downgraded.
#[test]
fn overload_downgrades_non_gold_and_accounts_for_it() {
    let model = lenet();
    let config = QosConfig {
        policy: BatchPolicy { max_batch: 2, linger: Duration::from_millis(1) },
        shed: ShedPolicy { enabled: true, queue_pressure: 2 },
        monitor: MonitorConfig { sample_every: 0, ..Default::default() },
        ..QosConfig::default()
    };
    let mut server = QosServer::start(model, &demo_lane_set(), config);
    // burst far beyond the pressure threshold before the worker can drain
    let imgs = images(48, 9);
    let classes: Vec<QosClass> = (0..imgs.len()).map(|i| QosClass::ALL[i % 3]).collect();
    let pending: Vec<_> = imgs
        .into_iter()
        .zip(&classes)
        .map(|(img, &c)| server.submit_with_deadline(c, img, Duration::from_secs(5)).unwrap())
        .collect();
    let responses: Vec<QosResponse> =
        pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let report = server.shutdown();

    // gold is never downgraded, even under pressure
    for r in responses.iter().filter(|r| r.class == QosClass::Gold) {
        assert!(!r.downgraded, "gold request downgraded");
        assert_eq!(r.served_by, "gold");
    }
    // the burst kept the backlog over the threshold: standard traffic
    // must have shed to the economy lane (and economy to the shed lane)
    let std_downgrades = report.metrics.class("standard").map(|c| c.downgrades).unwrap_or(0);
    let eco_downgrades = report.metrics.class("economy").map(|c| c.downgrades).unwrap_or(0);
    assert!(
        std_downgrades + eco_downgrades > 0,
        "no downgrades under synthetic overload: {:?}",
        report.metrics.summary()
    );
    // response flags agree with the metrics
    let flagged = responses.iter().filter(|r| r.downgraded).count() as u64;
    assert_eq!(flagged, std_downgrades + eco_downgrades);
    for r in responses.iter().filter(|r| r.downgraded) {
        match r.class {
            QosClass::Standard => assert_eq!(r.served_by, "economy"),
            QosClass::Economy => assert_eq!(r.served_by, "shed"),
            QosClass::Gold => panic!("gold downgraded"),
        }
    }
}

/// Deadline-aware batching: a request arriving during another request's
/// linger window joins that batch (closing it at `max_batch`) instead of
/// waiting for its own — and the batch closes well before the long
/// linger expires. (EDF *ordering* itself is covered deterministically
/// by the scheduler unit tests in `coordinator::qos`.)
#[test]
fn late_arrival_joins_the_lingering_batch() {
    let model = lenet();
    let linger = Duration::from_millis(400);
    let config = QosConfig {
        policy: BatchPolicy { max_batch: 2, linger },
        shed: ShedPolicy { enabled: false, queue_pressure: 0 },
        monitor: MonitorConfig { sample_every: 0, ..Default::default() },
        ..QosConfig::default()
    };
    let mut server = QosServer::start(model, &demo_lane_set(), config);
    let imgs = images(2, 5);
    let t0 = std::time::Instant::now();
    let first = server
        .submit_with_deadline(QosClass::Economy, imgs[0].clone(), Duration::from_secs(10))
        .unwrap();
    std::thread::sleep(Duration::from_millis(20)); // worker is now lingering
    let late = server
        .submit_with_deadline(QosClass::Economy, imgs[1].clone(), Duration::from_millis(50))
        .unwrap();
    let (r1, r2) = (first.recv().unwrap().unwrap(), late.recv().unwrap().unwrap());
    let elapsed = t0.elapsed();
    server.shutdown();
    assert_eq!(r1.batch_seq, r2.batch_seq, "late arrival did not join the lingering batch");
    assert_eq!(r1.batch_size, 2);
    assert!(
        elapsed < linger,
        "batch should close at max_batch, not at linger expiry ({elapsed:?})"
    );
}

/// The lane set built from autotuned frontier plans serves end to end
/// and its telemetry stays healthy under its own predicted bounds
/// (margin-tolerant), exercising autotune → lanes → QoS serving.
#[test]
fn autotuned_lane_set_serves_with_healthy_telemetry() {
    let model = lenet();
    let calib = images(2, 31);
    let opts = bfp_cnn::autotune::PlannerOptions { max_width: 9, min_width: 4, refine_rounds: 0 };
    let convs = bfp_cnn::autotune::calibrate(&model, &calib, &opts).unwrap();
    let plans = bfp_cnn::autotune::plan_lane_set(&model.name, &convs, 3, &opts);
    assert!(!plans.is_empty());
    let set = LaneSet::from_plans(&plans).unwrap();
    // frontier lanes: gold's operating plan is at least as safe as economy's
    assert!(
        set.gold.ladder[0].predicted_snr_db >= set.economy.ladder[0].predicted_snr_db,
        "lane set not ordered safest-first"
    );
    let config = QosConfig {
        policy: BatchPolicy { max_batch: 4, linger: Duration::from_millis(1) },
        shed: ShedPolicy { enabled: false, queue_pressure: 0 },
        // probe every batch with a wide margin: the surrogate is an
        // upper bound, so a generous margin must not trip a swap
        monitor: MonitorConfig {
            sample_every: 1,
            min_probes: 1,
            margin_db: 30.0,
            ..Default::default()
        },
        ..QosConfig::default()
    };
    let mut server = QosServer::start(model, &set, config);
    let imgs = images(9, 13);
    let pending: Vec<_> = imgs
        .iter()
        .enumerate()
        .map(|(i, img)| server.submit(QosClass::ALL[i % 3], img.clone()).unwrap())
        .collect();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let report = server.shutdown();
    for lane in report.lanes.iter().filter(|l| l.label != "shed") {
        assert!(lane.probes > 0, "lane {} never probed", lane.label);
        assert!(lane.measured_snr_db.is_finite());
        assert_eq!(lane.swaps, 0, "lane {} swapped under a 30 dB margin", lane.label);
    }
}

/// One shared weight cache across lanes: building the whole lane set
/// must not quantize a distinct weight format more than once.
#[test]
fn lane_construction_shares_the_weight_cache() {
    use bfp_cnn::nn::WeightCache;
    let model = lenet(); // 2 conv layers
    let cache = WeightCache::shared();
    // gold and standard share weight width 8 (formats equal), economy differs
    for cfg in [BfpConfig::new(8, 9), BfpConfig::new(8, 6), BfpConfig::new(5, 5)] {
        let lane = PreparedModel::with_cache(
            model.clone(),
            LayerSchedule::uniform(cfg),
            std::sync::Arc::clone(&cache),
        );
        lane.warm();
    }
    let stats = cache.lock().unwrap();
    assert_eq!(stats.misses(), 4, "weights quantized once per distinct format, not per lane");
    assert_eq!(stats.len(), 4);
    assert!(stats.hits() >= 2, "second lane should hit the shared cache");
}
