//! Property-style randomized tests over the BFP invariants (DESIGN.md §7).
//!
//! proptest is unavailable in the offline image, so each property is
//! driven by the library's deterministic [`Rng`] across a few hundred
//! random cases with mixed shapes, widths and distributions — failures
//! print the seed for replay.

use bfp_cnn::analysis::snr::{db_to_nsr, measured_snr};
use bfp_cnn::bfp::gemm::f32_gemm;
use bfp_cnn::bfp::partition::{BlockAxis, PartitionScheme};
use bfp_cnn::bfp::{bfp_gemm, block_format, dequantize, max_exponent, BfpFormat, BfpMatrix};
use bfp_cnn::data::Rng;
use bfp_cnn::quant::widths::WidthPlan;

fn random_values(rng: &mut Rng, n: usize) -> Vec<f32> {
    let kind = rng.below(4);
    let scale = 2f64.powf(rng.uniform_range(-8.0, 8.0));
    match kind {
        0 => rng.normal_vec(n, scale),
        1 => rng.laplacian_vec(n, scale),
        2 => (0..n).map(|_| rng.uniform_range(-scale, scale) as f32).collect(),
        _ => {
            // sparse with outliers — worst case for shared exponents
            let mut v = rng.normal_vec(n, scale * 0.01);
            if n > 0 {
                let idx = rng.below(n);
                v[idx] = (scale * 10.0) as f32;
            }
            v
        }
    }
}

/// |x − x'| ≤ Δ/2 for round-off (Δ for the saturated block max).
#[test]
fn prop_quantize_error_bounded() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..300 {
        let n = 1 + rng.below(257);
        let bits = 3 + rng.below(10) as u32;
        let xs = random_values(&mut rng, n);
        let fmt = BfpFormat::new(bits);
        let b = block_format(&xs, fmt);
        let Some(eps) = max_exponent(&xs) else { continue };
        let step = fmt.step(eps) as f64;
        for (x, y) in xs.iter().zip(b.to_f32()) {
            let err = (*x as f64 - y as f64).abs();
            assert!(err <= step * 1.0000001, "case {case}: |{x} - {y}| = {err} > step {step} (bits={bits})");
        }
    }
}

/// The block exponent equals the max element exponent.
#[test]
fn prop_block_exponent_is_max() {
    let mut rng = Rng::new(0xB0B);
    for _ in 0..300 {
        let n = 1 + rng.below(100);
        let xs = random_values(&mut rng, n);
        if let Some(eps) = max_exponent(&xs) {
            let b = block_format(&xs, BfpFormat::new(8));
            assert_eq!(b.exponent, eps);
            // every element's exponent ≤ block exponent
            for &x in &xs {
                if let Some(e) = bfp_cnn::bfp::exponent_of(x) {
                    assert!(e <= eps);
                }
            }
        }
    }
}

/// Quantization is a projection: quantizing an already-quantized block
/// changes nothing (idempotence).
#[test]
fn prop_quantize_idempotent() {
    let mut rng = Rng::new(0x1DE);
    for _ in 0..200 {
        let n = 1 + rng.below(128);
        let bits = 4 + rng.below(9) as u32;
        let xs = random_values(&mut rng, n);
        let once = dequantize(&xs, BfpFormat::new(bits));
        let twice = dequantize(&once, BfpFormat::new(bits));
        assert_eq!(once, twice, "bits={bits}");
    }
}

/// The fixed-point GEMM is bit-exact against an i128 integer reference —
/// the §3.4 width-plan guarantee, for every partition scheme.
#[test]
fn prop_gemm_exact_vs_integer_reference() {
    let mut rng = Rng::new(0x6E33);
    for case in 0..120 {
        let m = 1 + rng.below(12);
        let k = 1 + rng.below(48);
        let n = 1 + rng.below(24);
        let lw = 3 + rng.below(8) as u32;
        let li = 3 + rng.below(8) as u32;
        let scheme = match rng.below(4) {
            0 => PartitionScheme::Eq2,
            1 => PartitionScheme::Eq3,
            2 => PartitionScheme::Eq4,
            _ => PartitionScheme::Eq5,
        };
        let w = random_values(&mut rng, m * k);
        let i = random_values(&mut rng, k * n);
        let wq = BfpMatrix::quantize(&w, m, k, BfpFormat::new(lw), scheme.w_axis());
        let iq = BfpMatrix::quantize(&i, k, n, BfpFormat::new(li), scheme.i_axis());
        let o = bfp_gemm(&wq, &iq);
        // i128 mantissa reference
        for r in 0..m {
            for c in 0..n {
                let mut acc: i128 = 0;
                for kk in 0..k {
                    acc += wq.mantissas[r * k + kk] as i128 * iq.mantissas[kk * n + c] as i128;
                }
                let we = wq.exponent_at(r, 0);
                let ie = iq.exponent_at(0, c);
                let expect = if we <= i32::MIN / 4 || ie <= i32::MIN / 4 {
                    0.0
                } else {
                    acc as f64 * 2f64.powi(we + ie - wq.frac_bits - iq.frac_bits)
                };
                let got = o.data[r * n + c] as f64;
                let tol = expect.abs() * 1e-6 + 1e-30;
                assert!(
                    (got - expect).abs() <= tol,
                    "case {case} ({scheme:?}, lw={lw}, li={li}): O[{r},{c}] = {got} vs {expect}"
                );
            }
        }
    }
}

/// The planned accumulator width never saturates: worst-case |acc| fits.
#[test]
fn prop_width_plan_no_overflow() {
    let mut rng = Rng::new(0x57EE1);
    for _ in 0..300 {
        let k = 1 + rng.below(100_000);
        let lw = 3 + rng.below(14) as u32;
        let li = 3 + rng.below(14) as u32;
        let plan = WidthPlan::plan(k, lw, li);
        let worst = WidthPlan::worst_case_acc(k, lw, li);
        let cap = (1i128 << (plan.accumulator_bits - 1)) - 1;
        assert!(worst <= cap, "k={k} lw={lw} li={li}: {worst} > {cap}");
    }
}

/// Finer partitions never lose SNR: eq3 ≥ eq4/eq5 ≥ eq2 (within noise).
#[test]
fn prop_partition_snr_ordering() {
    let mut rng = Rng::new(0x0DD);
    for _ in 0..40 {
        let (m, k, n) = (8 + rng.below(16), 16 + rng.below(64), 8 + rng.below(32));
        let w = random_values(&mut rng, m * k);
        let i = random_values(&mut rng, k * n);
        let fmt = BfpFormat::new(8);
        let err = |axis: BlockAxis, data: &[f32], r: usize, c: usize| -> f64 {
            let q = BfpMatrix::quantize(data, r, c, fmt, axis);
            let back = q.to_f32();
            data.iter().zip(&back).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        // W: per-row ≤ whole; I: per-col ≤ whole (energy of quant error)
        assert!(err(BlockAxis::PerRow, &w, m, k) <= err(BlockAxis::Whole, &w, m, k) * 1.0001);
        assert!(err(BlockAxis::PerCol, &i, k, n) <= err(BlockAxis::Whole, &i, k, n) * 1.0001);
    }
}

/// Eq. (16) NSR additivity: measured output NSR ≈ η_W + η_I for
/// independent operands (within a factor ~2 — it's a statistical model).
#[test]
fn prop_nsr_additivity() {
    let mut rng = Rng::new(0xADD);
    for _ in 0..20 {
        let (m, k, n) = (32, 256, 64);
        let w = rng.laplacian_vec(m * k, 0.1);
        let i = rng.normal_vec(k * n, 1.0);
        let fmt = BfpFormat::new(8);
        let wq = BfpMatrix::quantize(&w, m, k, fmt, BlockAxis::PerRow);
        let iq = BfpMatrix::quantize(&i, k, n, fmt, BlockAxis::Whole);
        let o = bfp_gemm(&wq, &iq);
        let mut exact = vec![0f32; m * n];
        f32_gemm(&w, &i, m, k, n, &mut exact);
        let eta_o = db_to_nsr(measured_snr(&exact, &o.data));
        let eta_w = db_to_nsr(measured_snr(&w, &wq.to_f32()));
        let eta_i = db_to_nsr(measured_snr(&i, &iq.to_f32()));
        let predicted = eta_w + eta_i;
        assert!(
            eta_o / predicted < 2.5 && predicted / eta_o < 2.5,
            "eta_o {eta_o:.3e} vs predicted {predicted:.3e}"
        );
    }
}

/// Rounding beats truncation in quantization SNR (§3.1's argument).
#[test]
fn prop_rounding_beats_truncation() {
    let mut rng = Rng::new(0x7271);
    for _ in 0..50 {
        let n = 512 + rng.below(2048);
        let xs = random_values(&mut rng, n);
        if max_exponent(&xs).is_none() {
            continue;
        }
        let round_err: f64 = xs
            .iter()
            .zip(dequantize(&xs, BfpFormat::new(8)))
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let trunc_err: f64 = xs
            .iter()
            .zip(dequantize(&xs, BfpFormat::truncating(8)))
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        assert!(round_err <= trunc_err * 1.001, "round {round_err} vs trunc {trunc_err}");
    }
}

/// Truncation has a DC bias toward zero; rounding is (near) unbiased —
/// the mechanism behind the paper's layer-wise bias-accumulation warning.
#[test]
fn prop_truncation_bias_rounding_unbiased() {
    let mut rng = Rng::new(0xB1A5);
    let n = 200_000;
    let xs: Vec<f32> = (0..n).map(|_| rng.uniform_range(0.5, 1.9) as f32).collect();
    let mean_err = |fmt: BfpFormat| -> f64 {
        xs.iter().zip(dequantize(&xs, fmt)).map(|(a, b)| (b - a) as f64).sum::<f64>() / n as f64
    };
    let round_bias = mean_err(BfpFormat::new(8));
    let trunc_bias = mean_err(BfpFormat::truncating(8));
    let step = BfpFormat::new(8).step(0) as f64;
    assert!(round_bias.abs() < step * 0.02, "rounding bias {round_bias} vs step {step}");
    // truncation of positive values biases low by ~step/2
    assert!(trunc_bias < -step * 0.3, "truncation bias {trunc_bias} vs step {step}");
}
