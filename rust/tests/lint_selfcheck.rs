//! Integration tests for `bfp-cnn lint`: the committed tree must be
//! clean against the committed baseline, the seeded fixture files under
//! `tests/fixtures/lint/` must fire exactly the expected rules when
//! planted in a pretend repo, and a full baseline must grandfather
//! every finding.

use bfp_cnn::analysis::lint::{baseline_key, collect_sources, load_baseline, repo_root};
use bfp_cnn::analysis::rules::run_all;
use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

/// Minimal wire-clean cross-file inputs so `rule_wire_exhaustive` has
/// its three files and emits nothing for the pretend repo.
const WIRE_QOS: &str = "pub enum QosErrorKind {\n    Timeout,\n}\n";
const WIRE_SERVER: &str = "pub fn map() {\n    let _ = QosErrorKind::Timeout;\n}\n";
const WIRE_PROTO: &str = r#"pub const KIND_PING: u8 = 1;

pub fn enc(mut w: impl FnMut(u8)) {
    w(KIND_PING);
}

pub fn dec(r: u8) -> bool {
    r == KIND_PING
}

#[cfg(test)]
mod tests {
    fn round_trip() {
        encode_ping(1);
    }
}
"#;

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/lint").join(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// Plant the seeded fixtures in a fresh temp repo under pretend serving
/// paths, so every path-scoped rule is in scope for them.
fn build_temp_repo(tag: &str) -> PathBuf {
    let root = std::env::temp_dir().join(format!("bfp_lint_it_{tag}_{}", std::process::id()));
    fs::remove_dir_all(&root).ok();
    let rust = root.join("rust");
    for (rel, body) in [
        ("src/bfp/bad_unsafe.rs", fixture("bad_unsafe.rs")),
        ("src/coordinator/bad_sleep.rs", fixture("bad_sleep.rs")),
        ("src/net/bad_clock.rs", fixture("bad_clock.rs")),
        ("src/net/bad_unwrap.rs", fixture("bad_unwrap.rs")),
        ("src/obs/bad_ordering.rs", fixture("bad_ordering.rs")),
        ("src/runtime/bad_lock_order.rs", fixture("bad_lock_order.rs")),
        ("src/net/allowed_clean.rs", fixture("allowed_clean.rs")),
        ("src/coordinator/qos.rs", WIRE_QOS.to_string()),
        ("src/net/server.rs", WIRE_SERVER.to_string()),
        ("src/net/proto.rs", WIRE_PROTO.to_string()),
    ] {
        let p = rust.join(rel);
        fs::create_dir_all(p.parent().unwrap()).unwrap();
        fs::write(&p, body).unwrap();
    }
    root
}

#[test]
fn real_tree_is_clean_against_committed_baseline() {
    let root = repo_root().expect("repo root not found");
    let tree = collect_sources(&root).expect("collect sources");
    let violations = run_all(&tree.lexed);
    let baseline = load_baseline(&root.join("rust/analysis/baseline.txt"));
    let new: Vec<String> = violations
        .iter()
        .filter(|v| !baseline.contains(&baseline_key(v, &tree)))
        .map(|v| v.to_string())
        .collect();
    assert!(
        new.is_empty(),
        "lint found new violations in the real tree:\n{}",
        new.join("\n")
    );
    let current: BTreeSet<String> = violations.iter().map(|v| baseline_key(v, &tree)).collect();
    let stale: Vec<&String> = baseline.difference(&current).collect();
    assert!(stale.is_empty(), "stale baseline entries: {stale:?}");
}

#[test]
fn seeded_fixtures_fire_exactly_the_expected_rules() {
    let root = build_temp_repo("fixtures");
    let tree = collect_sources(&root).expect("collect temp sources");
    let violations = run_all(&tree.lexed);
    let mut got: Vec<(String, &str)> =
        violations.iter().map(|v| (v.path.clone(), v.rule)).collect();
    got.sort();
    let want = vec![
        ("src/bfp/bad_unsafe.rs".to_string(), "unsafe-safety"),
        ("src/coordinator/bad_sleep.rs".to_string(), "bare-sleep"),
        ("src/net/bad_clock.rs".to_string(), "clock-source"),
        ("src/net/bad_unwrap.rs".to_string(), "serving-unwrap"),
        ("src/obs/bad_ordering.rs".to_string(), "ordering-comment"),
        ("src/obs/bad_ordering.rs".to_string(), "ordering-comment"),
        ("src/runtime/bad_lock_order.rs".to_string(), "lock-order"),
    ];
    assert_eq!(
        got,
        want,
        "unexpected finding set:\n{}",
        violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
    );
    fs::remove_dir_all(&root).ok();
}

#[test]
fn baseline_grandfathers_every_finding() {
    let root = build_temp_repo("baseline");
    let tree = collect_sources(&root).expect("collect temp sources");
    let violations = run_all(&tree.lexed);
    assert!(!violations.is_empty(), "fixture tree should have findings");
    let keys: BTreeSet<String> = violations.iter().map(|v| baseline_key(v, &tree)).collect();
    let path = root.join("rust/analysis/baseline.txt");
    fs::create_dir_all(path.parent().unwrap()).unwrap();
    let mut body = String::from("# grandfathered by the round-trip test\n\n");
    for k in &keys {
        body.push_str(k);
        body.push('\n');
    }
    fs::write(&path, body).unwrap();
    let loaded = load_baseline(&path);
    assert_eq!(loaded, keys, "baseline must round-trip through the parser");
    let new = violations.iter().filter(|v| !loaded.contains(&baseline_key(v, &tree))).count();
    assert_eq!(new, 0, "a full baseline must grandfather every finding");
    fs::remove_dir_all(&root).ok();
}
