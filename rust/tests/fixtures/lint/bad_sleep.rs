// Seeded fixture: bare sleep on a serving path.
use std::time::Duration;

pub fn nap() {
    std::thread::sleep(Duration::from_millis(1));
}
