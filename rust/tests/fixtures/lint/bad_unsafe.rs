// Seeded fixture: `unsafe` with no SAFETY comment anywhere above it.
pub fn read_first(p: *const u8) -> u8 {
    unsafe { *p }
}
