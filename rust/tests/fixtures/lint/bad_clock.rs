// Seeded fixture: raw time source on a serving path.
use std::time::Instant;

pub fn stamp() -> Instant {
    Instant::now()
}
