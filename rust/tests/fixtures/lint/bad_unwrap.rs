// Seeded fixture: unwrap on the serving path. The mutex poison unwrap
// below must NOT fire (structural exclusion).
use std::sync::Mutex;

pub fn first(v: &[u8]) -> u8 {
    *v.first().unwrap()
}

pub fn guarded(m: &Mutex<u8>) -> u8 {
    *m.lock().unwrap()
}
