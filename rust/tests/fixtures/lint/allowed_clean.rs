// Negative fixture: every marker/justification form the linter
// accepts. Linting this file under a serving path must yield zero
// findings.
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

pub fn operator_timer() -> Instant {
    // LINT-ALLOW: clock-source — operator-facing timer; wall time is
    // exactly what we want to show
    Instant::now()
}

pub fn paced_wait() {
    // LINT-ALLOW: bare-sleep — pacing against a remote peer needs real
    // wall time
    std::thread::sleep(Duration::from_millis(1));
}

pub fn read_ptr(p: *const u8) -> u8 {
    // SAFETY: the caller guarantees `p` points at a live byte
    unsafe { *p }
}

pub fn publish(flag: &AtomicBool) {
    // Release: pairs with the Acquire load in the reader
    flag.store(true, Ordering::Release);
}

pub fn stop(flag: &AtomicBool) {
    // SeqCst: cold shutdown flag; keep the total order for simplicity
    flag.store(true, Ordering::SeqCst);
}

// LOCK-ORDER: a before b, everywhere
pub fn both(a: &Mutex<u8>, b: &Mutex<u8>) -> u8 {
    let x = *a.lock().unwrap();
    let y = *b.lock().unwrap();
    x + y
}
