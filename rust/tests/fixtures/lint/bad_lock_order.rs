// Seeded fixture: two lock acquisitions in one fn, no LOCK-ORDER
// annotation.
use std::sync::Mutex;

pub fn both(a: &Mutex<u8>, b: &Mutex<u8>) -> u8 {
    let x = *a.lock().unwrap();
    let y = *b.lock().unwrap();
    x + y
}
