// Seeded fixture: atomic orderings with no justification comment, and
// a SeqCst whose comment never explains why SeqCst.
use std::sync::atomic::{AtomicBool, Ordering};

pub fn set(flag: &AtomicBool) {
    flag.store(true, Ordering::Relaxed);
}

pub fn set_strong(flag: &AtomicBool) {
    // stop flag for shutdown
    flag.store(true, Ordering::SeqCst);
}
