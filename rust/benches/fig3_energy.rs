//! Bench for Figure 3: energy-histogram computation over captured layer
//! outputs, plus the rendered figure table.

use bfp_cnn::analysis::energy::EnergyHistogram;
use bfp_cnn::data::Rng;
use bfp_cnn::harness::benchkit::{bench, section};
use bfp_cnn::harness::fig3;
use std::path::Path;

fn main() {
    section("Figure 3 — histogram throughput");
    let mut rng = Rng::new(1);
    let values = rng.normal_vec(1 << 20, 1.0);
    bench("energy_histogram_1M", Some((1 << 20) as f64), "elem", || {
        std::hint::black_box(EnergyHistogram::compute(&values, 50));
    });

    section("Figure 3 — layer capture + render (2 images, VGG-16/32px)");
    bench("fig3_capture_and_render", Some(1.0), "run", || {
        std::hint::black_box(fig3::run(32, 2, 3, Path::new("artifacts")));
    });

    section("Figure 3 — rendered (5 images)");
    fig3::run(32, 5, 3, Path::new("artifacts")).print();
}
