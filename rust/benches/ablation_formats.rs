//! Ablation: BFP (eq. 4) vs the §2 related-work formats, plus the
//! rounding-mode ablation — the design-space arguments DESIGN.md calls
//! out, run on the trained cifar net and on conv-shaped data.
//!
//! Expected shape (the paper's motivation):
//! * uniform fixed point needs several more bits than BFP for the same
//!   quantization SNR once the data spans many octaves (Hill et al.'s
//!   40-bit GoogLeNet observation);
//! * dynamic fixed point (whole-matrix scaling) sits between;
//! * round-off beats truncation (DC bias) and stochastic rounding (2×
//!   error energy) for inference.

use bfp_cnn::bfp::format::Rounding;
use bfp_cnn::bfp::{dequantize, BfpFormat, BfpMatrix, PartitionScheme};
use bfp_cnn::data::Rng;
use bfp_cnn::harness::benchkit::section;
use bfp_cnn::harness::table3::{drop_for, prepare_model_and_set};
use bfp_cnn::models::ModelId;
use bfp_cnn::quant::baselines::FixedPointFormat;
use bfp_cnn::quant::BfpConfig;
use std::path::Path;

fn main() {
    section("quantization SNR vs width — conv activations (imagenet-like stats)");
    // activation-shaped data: heavy-tailed, wide dynamic range
    let mut rng = Rng::new(3);
    let mut xs = rng.laplacian_vec(1 << 16, 1.0);
    xs.extend(rng.laplacian_vec(1 << 10, 20.0)); // rare large activations
    let max_abs = xs.iter().fold(0f32, |m, &v| m.max(v.abs()));
    let snr = |ys: &[f32]| {
        let sig: f64 = xs.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let err: f64 = xs.iter().zip(ys).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        10.0 * (sig / err).log10()
    };
    println!("{:<6} {:>14} {:>16} {:>18}", "bits", "BFP per-row", "dyn-fixed (eq2)", "uniform fixed");
    for bits in [6u32, 8, 10, 12, 16] {
        let rows = 256;
        let cols = xs.len() / rows;
        let per_row = BfpMatrix::quantize(
            &xs[..rows * cols],
            rows,
            cols,
            BfpFormat::new(bits),
            bfp_cnn::bfp::partition::BlockAxis::PerRow,
        )
        .to_f32();
        let mut padded = per_row;
        padded.extend_from_slice(&xs[rows * cols..]); // tail unquantized (tiny)
        let dynfix = dequantize(&xs, BfpFormat::new(bits));
        let fixed = FixedPointFormat::for_range(bits, max_abs).quantize_slice(&xs);
        println!(
            "{bits:<6} {:>11.2} dB {:>13.2} dB {:>15.2} dB",
            snr(&padded),
            snr(&dynfix),
            snr(&fixed)
        );
    }

    section("accuracy drop vs format — trained cifar net (60 images)");
    let artifacts = Path::new("artifacts");
    let (model, set) = prepare_model_and_set(ModelId::Cifar10, 32, 60, 1, artifacts);
    println!("{:<8} {:>12} {:>12} {:>12}", "width", "eq4 (paper)", "eq2 (dyn)", "eq3 (vector)");
    for bits in [4u32, 5, 6, 8] {
        let d4 = drop_for(&model, &set, BfpConfig::new(bits, bits));
        let d2 = drop_for(&model, &set, BfpConfig::new(bits, bits).with_scheme(PartitionScheme::Eq2));
        let d3 = drop_for(&model, &set, BfpConfig::new(bits, bits).with_scheme(PartitionScheme::Eq3));
        println!("{bits:<8} {d4:>12.4} {d2:>12.4} {d3:>12.4}");
    }

    section("rounding-mode ablation — trained cifar net (60 images)");
    println!("{:<8} {:>12} {:>12} {:>12}", "width", "round-off", "truncate", "stochastic");
    for bits in [4u32, 5, 6, 8] {
        let base = BfpConfig::new(bits, bits);
        let dn = drop_for(&model, &set, base);
        let dt = drop_for(&model, &set, base.with_rounding(Rounding::Truncate));
        let ds = drop_for(&model, &set, base.with_rounding(Rounding::Stochastic));
        println!("{bits:<8} {dn:>12.4} {dt:>12.4} {ds:>12.4}");
    }
    println!("\n(accuracy at tiny widths is noisy on 60 images; the §3.1 rounding-vs-\n truncation claim is asserted statistically in rust/tests/proptests.rs:\n prop_rounding_beats_truncation / prop_truncation_bias_rounding_unbiased)");
}
