//! Bench for Figure 2: the fixed-point data flow vs the floating-point
//! baseline, across conv-shaped GEMMs.
//!
//! Paper claim shape: the BFP pipeline's MACs run in integer arithmetic
//! (cheap on FPGA: a 32-bit fixed adder costs 1 DSP vs 2 DSP + 117 LUT
//! for an fp16 adder, §3.1). On a CPU the analogous observable is that
//! the i32 mantissa GEMM sustains comparable-or-better MAC throughput
//! than f32 GEMM while moving 4× fewer weight/activation bits (Table 1);
//! we report MAC/s for both paths plus the end-to-end BFP pipeline
//! (quantize + GEMM + rescale).

use bfp_cnn::bfp::gemm::f32_gemm;
use bfp_cnn::bfp::{bfp_gemm, BfpMatrix, PartitionScheme};
use bfp_cnn::data::Rng;
use bfp_cnn::harness::benchkit::{bench, section};
use bfp_cnn::quant::BfpConfig;

fn main() {
    // conv-shaped GEMMs: (tag, M, K, N) from VGG-16 at 64×64 input
    let shapes = [
        ("conv1_1-like", 64usize, 27usize, 4096usize),
        ("conv2_2-like", 128, 1152, 1024),
        ("conv4_1-like", 512, 2304, 64),
        ("fc-like", 512, 2048, 8),
    ];
    let cfg = BfpConfig::paper_default();
    for (tag, m, k, n) in shapes {
        section(&format!("{tag}: O[{m}x{n}] = W[{m}x{k}] · I[{k}x{n}]"));
        let mut rng = Rng::new(7);
        let w = rng.laplacian_vec(m * k, 0.05);
        let i = rng.normal_vec(k * n, 1.0);
        let macs = (m * k * n) as f64;

        let mut out = vec![0f32; m * n];
        bench("f32_gemm", Some(macs), "MAC", || {
            f32_gemm(&w, &i, m, k, n, &mut out);
            std::hint::black_box(&out);
        });

        // quantize once, GEMM many (weights static, activations per batch)
        let wq = BfpMatrix::quantize(&w, m, k, cfg.w_format(), cfg.scheme.w_axis());
        let iq = BfpMatrix::quantize(&i, k, n, cfg.i_format(), cfg.scheme.i_axis());
        bench("bfp_mantissa_gemm (fixed-point MAC)", Some(macs), "MAC", || {
            std::hint::black_box(bfp_gemm(&wq, &iq));
        });

        bench("bfp_pipeline (quantize I + gemm)", Some(macs), "MAC", || {
            let iq = BfpMatrix::quantize(&i, k, n, cfg.i_format(), cfg.scheme.i_axis());
            std::hint::black_box(bfp_gemm(&wq, &iq));
        });

        // exactness invariant of the Figure 2 flow (§3.4)
        let o_bfp = bfp_gemm(&wq, &iq);
        let wd = wq.to_f32();
        let id = iq.to_f32();
        let mut o_ref = vec![0f32; m * n];
        f32_gemm(&wd, &id, m, k, n, &mut o_ref);
        assert_eq!(o_bfp.data, o_ref, "fixed-point MAC must be exact");
        println!("exactness: fixed-point MAC bit-exact vs dequantized f32 GEMM ✓");
    }

    section("eq2 vs eq4 output SNR at conv2_2 shape (Table 2 mechanism)");
    let (m, k, n) = (128usize, 1152usize, 1024usize);
    let mut rng = Rng::new(9);
    let w = rng.laplacian_vec(m * k, 0.05);
    let i = rng.normal_vec(k * n, 1.0);
    let mut exact = vec![0f32; m * n];
    f32_gemm(&w, &i, m, k, n, &mut exact);
    for scheme in [PartitionScheme::Eq2, PartitionScheme::Eq4] {
        let c = BfpConfig::paper_default().with_scheme(scheme);
        let wq = BfpMatrix::quantize(&w, m, k, c.w_format(), c.scheme.w_axis());
        let iq = BfpMatrix::quantize(&i, k, n, c.i_format(), c.scheme.i_axis());
        let o = bfp_gemm(&wq, &iq);
        let sig: f64 = exact.iter().map(|x| (*x as f64).powi(2)).sum();
        let err: f64 = exact.iter().zip(&o.data).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        println!("{scheme:?}: output SNR {:.2} dB", 10.0 * (sig / err).log10());
    }
}
