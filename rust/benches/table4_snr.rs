//! Bench for Table 4: the dual-forward instrumentation plus the §4
//! theory evaluation on VGG-16, and the resulting theory-vs-experiment
//! deviation (the paper's ≤ 8.9 dB claim).

use bfp_cnn::analysis::multi_layer::propagate_multi_layer;
use bfp_cnn::harness::benchkit::{bench, section};
use bfp_cnn::harness::table4::{gather, max_deviation};
use bfp_cnn::models::ModelId;
use bfp_cnn::quant::BfpConfig;
use std::path::Path;

fn main() {
    let artifacts = Path::new("artifacts");
    let model = ModelId::Vgg16.build(32, 1, artifacts);

    section("Table 4 — instrumented dual forward (1 image, VGG-16/32px)");
    bench("dual_forward_instrumented", Some(1.0), "img", || {
        std::hint::black_box(gather(&model, BfpConfig::paper_default(), 1, 3));
    });

    section("Table 4 — multi-layer propagation model over 13 conv records");
    let data = gather(&model, BfpConfig::paper_default(), 2, 3);
    bench("propagate_multi_layer", Some(13.0), "layer", || {
        std::hint::black_box(propagate_multi_layer(&data.records));
    });

    let dev = max_deviation(&data);
    println!("\nmax |multi − ex| conv-output deviation: {dev:.2} dB (paper: ≤ 8.9 dB)");
    assert!(dev.is_finite());
}
