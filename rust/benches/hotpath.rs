//! Hot-path micro-benches for the §Perf optimization loop: block
//! formatting, the mantissa GEMM inner loops, im2col, and the whole BFP
//! conv layer. Run before/after each optimization; numbers recorded in
//! EXPERIMENTS.md §Perf.

use bfp_cnn::bfp::gemm::f32_gemm;
use bfp_cnn::bfp::{bfp_gemm, block_format, max_exponent, BfpFormat, BfpMatrix};
use bfp_cnn::bfp::partition::BlockAxis;
use bfp_cnn::data::Rng;
use bfp_cnn::harness::benchkit::{bench, section};
use bfp_cnn::nn::Conv2d;
use bfp_cnn::tensor::{im2col, Conv2dGeometry, Tensor};

fn main() {
    let mut rng = Rng::new(1);

    section("quantize: max_exponent scan");
    let xs = rng.normal_vec(1 << 20, 1.0);
    bench("max_exponent_1M", Some((1 << 20) as f64), "elem", || {
        std::hint::black_box(max_exponent(&xs));
    });

    section("quantize: full block format (1M elements, L=8)");
    let fmt = BfpFormat::new(8);
    bench("block_format_1M", Some((1 << 20) as f64), "elem", || {
        std::hint::black_box(block_format(&xs, fmt));
    });
    bench("bfp_matrix_whole_1M", Some((1 << 20) as f64), "elem", || {
        std::hint::black_box(BfpMatrix::quantize(&xs, 1024, 1024, fmt, BlockAxis::Whole));
    });
    bench("bfp_matrix_per_row_1M", Some((1 << 20) as f64), "elem", || {
        std::hint::black_box(BfpMatrix::quantize(&xs, 1024, 1024, fmt, BlockAxis::PerRow));
    });

    section("GEMM inner loops (conv3_1-like: 256x1152 @ 1152x256)");
    let (m, k, n) = (256usize, 1152usize, 256usize);
    let w = rng.laplacian_vec(m * k, 0.05);
    let i = rng.normal_vec(k * n, 1.0);
    let macs = (m * k * n) as f64;
    let mut out = vec![0f32; m * n];
    bench("f32_gemm", Some(macs), "MAC", || {
        f32_gemm(&w, &i, m, k, n, &mut out);
        std::hint::black_box(&out);
    });
    let wq = BfpMatrix::quantize(&w, m, k, fmt, BlockAxis::PerRow);
    let iq = BfpMatrix::quantize(&i, k, n, fmt, BlockAxis::Whole);
    bench("bfp_gemm (8-bit, f32-mantissa lane)", Some(macs), "MAC", || {
        std::hint::black_box(bfp_gemm(&wq, &iq));
    });
    // force the i64 lane for comparison
    let fmt16 = BfpFormat::new(16);
    let wq16 = BfpMatrix::quantize(&w, m, k, fmt16, BlockAxis::PerRow);
    let iq16 = BfpMatrix::quantize(&i, k, n, fmt16, BlockAxis::Whole);
    bench("bfp_gemm (16-bit, i64 lane)", Some(macs), "MAC", || {
        std::hint::black_box(bfp_gemm(&wq16, &iq16));
    });

    section("im2col (3x64x64, 3x3 kernel, pad 1)");
    let img = rng.normal_vec(3 * 64 * 64, 1.0);
    let geo = Conv2dGeometry {
        in_channels: 3,
        in_h: 64,
        in_w: 64,
        kernel_h: 3,
        kernel_w: 3,
        stride: 1,
        padding: 1,
    };
    let mut col = vec![0f32; geo.k() * geo.n()];
    bench("im2col_3x64x64", Some((geo.k() * geo.n()) as f64), "elem", || {
        im2col(&img, &geo, &mut col);
        std::hint::black_box(&col);
    });

    section("end-to-end BFP conv layer (64ch → 64ch, 32x32)");
    let weights = Tensor::from_vec(rng.laplacian_vec(64 * 64 * 9, 0.05), &[64, 64, 3, 3]);
    let conv = Conv2d::new("bench", weights, vec![0.0; 64], 1, 1);
    let input = Tensor::from_vec(rng.normal_vec(64 * 32 * 32, 1.0), &[64, 32, 32]);
    let layer_macs = (64 * 64 * 9 * 32 * 32) as f64;
    bench("conv_fp32", Some(layer_macs), "MAC", || {
        std::hint::black_box(conv.forward_fp32(&input));
    });
    bench("conv_bfp", Some(layer_macs), "MAC", || {
        std::hint::black_box(conv.forward_bfp(&input, &bfp_cnn::quant::BfpConfig::paper_default()));
    });
}
