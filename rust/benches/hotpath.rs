//! Hot-path micro-benches for the §Perf optimization loop: block
//! formatting, the mantissa GEMM inner loops, im2col, the whole BFP conv
//! layer, and the prepared-model serving path (cached weight
//! quantization + scratch arenas + panel-parallel GEMM). Run
//! before/after each optimization; numbers recorded in EXPERIMENTS.md
//! §Perf and emitted machine-readably to `BENCH_hotpath.json` (override
//! the path with `BENCH_JSON=...`).
//!
//! `cargo bench --bench hotpath -- --smoke` runs every bench at tiny
//! shapes in a few seconds — the CI smoke job uses it so the perf
//! harness can never silently rot.
//!
//! `-- --baseline[=<path>]` additionally diffs the run against a
//! committed `BENCH_hotpath.json` (default: the tracked workspace-root
//! copy) and exits non-zero on any >15% mean-time regression, provided
//! the baseline has comparable entries and matching shapes (tag).

use bfp_cnn::bfp::gemm::f32_gemm;
use bfp_cnn::bfp::kernel::{gemm_tiled, pack_weights_f32, pack_weights_i32, select_lane, ActPanels, WeightPanels};
use bfp_cnn::bfp::partition::BlockAxis;
use bfp_cnn::bfp::{bfp_gemm, block_format, max_exponent, BfpFormat, BfpMatrix};
use bfp_cnn::data::Rng;
use bfp_cnn::harness::benchkit::{
    bench_opts, diff_against_baseline, read_baseline, report_baseline_diff, section, write_json,
    BenchOpts, BenchResult,
};
use bfp_cnn::models::Model;
use bfp_cnn::nn::prepared::PreparedModel;
use bfp_cnn::nn::{Block, Conv2d};
use bfp_cnn::quant::{BfpConfig, LayerSchedule};
use bfp_cnn::runtime::pool;
use bfp_cnn::tensor::{im2col, Conv2dGeometry, Tensor};
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke") || std::env::var("BENCH_SMOKE").is_ok();
    let opts = if smoke {
        BenchOpts { min_time: Duration::from_millis(10), max_iters: 12 }
    } else {
        BenchOpts::default()
    };
    let mut results: Vec<BenchResult> = Vec::new();
    let mut rng = Rng::new(1);
    if smoke {
        println!("(smoke mode: tiny shapes, few iterations)");
    }

    section("quantize: max_exponent scan + full block format (L=8)");
    let quant_n = if smoke { 1 << 14 } else { 1 << 20 };
    let side = (quant_n as f64).sqrt() as usize;
    let xs = rng.normal_vec(quant_n, 1.0);
    let fmt = BfpFormat::new(8);
    results.push(bench_opts("max_exponent", Some(quant_n as f64), "elem", opts, &mut || {
        std::hint::black_box(max_exponent(&xs));
    }));
    results.push(bench_opts("block_format", Some(quant_n as f64), "elem", opts, &mut || {
        std::hint::black_box(block_format(&xs, fmt));
    }));
    results.push(bench_opts("bfp_matrix_whole", Some(quant_n as f64), "elem", opts, &mut || {
        std::hint::black_box(BfpMatrix::quantize(&xs, side, side, fmt, BlockAxis::Whole));
    }));
    results.push(bench_opts("bfp_matrix_per_row", Some(quant_n as f64), "elem", opts, &mut || {
        std::hint::black_box(BfpMatrix::quantize(&xs, side, side, fmt, BlockAxis::PerRow));
    }));

    section("GEMM inner loops (conv3_1-like: M x K @ K x N)");
    let (m, k, n) = if smoke { (32usize, 144usize, 64usize) } else { (256usize, 1152usize, 256usize) };
    let w = rng.laplacian_vec(m * k, 0.05);
    let i = rng.normal_vec(k * n, 1.0);
    let macs = (m * k * n) as f64;
    let mut out = vec![0f32; m * n];
    // serial pins: legacy-named benches stay comparable with PR 1
    // baselines; the *_t{N} sweeps below measure thread scaling.
    results.push(pool::with_threads(1, || {
        bench_opts("f32_gemm", Some(macs), "MAC", opts, &mut || {
            f32_gemm(&w, &i, m, k, n, &mut out);
            std::hint::black_box(&out);
        })
    }));
    let wq = BfpMatrix::quantize(&w, m, k, fmt, BlockAxis::PerRow);
    let iq = BfpMatrix::quantize(&i, k, n, fmt, BlockAxis::Whole);
    results.push(pool::with_threads(1, || {
        bench_opts("bfp_gemm_8bit_f32_lane", Some(macs), "MAC", opts, &mut || {
            std::hint::black_box(bfp_gemm(&wq, &iq));
        })
    }));
    // force the i64 lane for comparison
    let fmt16 = BfpFormat::new(16);
    let wq16 = BfpMatrix::quantize(&w, m, k, fmt16, BlockAxis::PerRow);
    let iq16 = BfpMatrix::quantize(&i, k, n, fmt16, BlockAxis::Whole);
    results.push(pool::with_threads(1, || {
        bench_opts("bfp_gemm_16bit_i64_lane", Some(macs), "MAC", opts, &mut || {
            std::hint::black_box(bfp_gemm(&wq16, &iq16));
        })
    }));
    // panel-parallel scaling on the 8-bit lane
    for t in [1usize, 2, 4] {
        results.push(pool::with_threads(t, || {
            bench_opts(&format!("bfp_gemm_8bit_t{t}"), Some(macs), "MAC", opts, &mut || {
                std::hint::black_box(bfp_gemm(&wq, &iq));
            })
        }));
    }

    section("tiled microkernel vs naive reference (pre-packed operands)");
    let lane8 = select_lane(wq.frac_bits, iq.frac_bits, k);
    let wq_panels = pack_weights_f32(&wq);
    let mut acts8 = ActPanels::new();
    acts8.pack_matrix(&iq, lane8);
    results.push(pool::with_threads(1, || {
        bench_opts("bfp_gemm_8bit_tiled", Some(macs), "MAC", opts, &mut || {
            gemm_tiled(&wq, WeightPanels::F32(&wq_panels), &acts8, &mut out);
            std::hint::black_box(&out);
        })
    }));
    // 2D (M panel × N block) scaling of the tiled kernel
    for t in [1usize, 2, 4] {
        results.push(pool::with_threads(t, || {
            bench_opts(&format!("bfp_gemm_8bit_tiled_t{t}"), Some(macs), "MAC", opts, &mut || {
                gemm_tiled(&wq, WeightPanels::F32(&wq_panels), &acts8, &mut out);
                std::hint::black_box(&out);
            })
        }));
    }
    // wide-mantissa i64 lane, tiled vs the naive reference above
    let lane16 = select_lane(wq16.frac_bits, iq16.frac_bits, k);
    let wq16_panels = pack_weights_i32(&wq16);
    let mut acts16 = ActPanels::new();
    acts16.pack_matrix(&iq16, lane16);
    results.push(pool::with_threads(1, || {
        bench_opts("bfp_gemm_16bit_tiled", Some(macs), "MAC", opts, &mut || {
            gemm_tiled(&wq16, WeightPanels::Int(&wq16_panels), &acts16, &mut out);
            std::hint::black_box(&out);
        })
    }));

    section("im2col (3x3 kernel, pad 1)");
    let im_side = if smoke { 16 } else { 64 };
    let img = rng.normal_vec(3 * im_side * im_side, 1.0);
    let geo = Conv2dGeometry {
        in_channels: 3,
        in_h: im_side,
        in_w: im_side,
        kernel_h: 3,
        kernel_w: 3,
        stride: 1,
        padding: 1,
    };
    let mut col = vec![0f32; geo.k() * geo.n()];
    results.push(bench_opts("im2col_3ch", Some((geo.k() * geo.n()) as f64), "elem", opts, &mut || {
        im2col(&img, &geo, &mut col);
        std::hint::black_box(&col);
    }));

    section("end-to-end BFP conv layer (square channels)");
    let (ch, sp) = if smoke { (8usize, 8usize) } else { (64usize, 32usize) };
    let weights = Tensor::from_vec(rng.laplacian_vec(ch * ch * 9, 0.05), &[ch, ch, 3, 3]);
    let conv = Conv2d::new("bench", weights, vec![0.0; ch], 1, 1);
    let input = Tensor::from_vec(rng.normal_vec(ch * sp * sp, 1.0), &[ch, sp, sp]);
    let layer_macs = (ch * ch * 9 * sp * sp) as f64;
    let cfg = BfpConfig::paper_default();
    results.push(pool::with_threads(1, || {
        bench_opts("conv_fp32", Some(layer_macs), "MAC", opts, &mut || {
            std::hint::black_box(conv.forward_fp32(&input));
        })
    }));
    results.push(pool::with_threads(1, || {
        bench_opts("conv_bfp", Some(layer_macs), "MAC", opts, &mut || {
            std::hint::black_box(conv.forward_bfp(&input, &cfg));
        })
    }));

    section("prepared-model serving (conv3_1-like conv, warm cache)");
    // conv3_1-like: K = cin*9, spatial N = sp31^2
    let (cout31, cin31, sp31) = if smoke { (32usize, 16usize, 8usize) } else { (256usize, 128usize, 16usize) };
    let w31 = Tensor::from_vec(rng.laplacian_vec(cout31 * cin31 * 9, 0.05), &[cout31, cin31, 3, 3]);
    let conv31 = Conv2d::new("conv3_1", w31, vec![0.0; cout31], 1, 1);
    let input31 = Tensor::from_vec(rng.normal_vec(cin31 * sp31 * sp31, 1.0), &[cin31, sp31, sp31]);
    let macs31 = (cout31 * cin31 * 9 * sp31 * sp31) as f64;
    results.push(pool::with_threads(1, || {
        bench_opts("conv3_1_bfp_cold", Some(macs31), "MAC", opts, &mut || {
            // cold path: re-quantizes + re-packs weights and allocates
            // per call, pinned serial — the cost the prepared path
            // amortizes (the kernel itself is tiled as of PR 4)
            std::hint::black_box(conv31.forward_bfp(&input31, &cfg));
        })
    }));
    let model31 = Model {
        name: "conv3_1".into(),
        graph: Block::seq(vec![Block::Conv(conv31.clone())]),
        input_shape: vec![cin31, sp31, sp31],
        num_classes: 0,
    };
    let prepared = PreparedModel::new(model31, LayerSchedule::uniform(cfg));
    prepared.warm();
    results.push(bench_opts("conv3_1_prepared_warm", Some(macs31), "MAC", opts, &mut || {
        std::hint::black_box(prepared.forward(&input31));
    }));
    for t in [1usize, 2, 4] {
        results.push(pool::with_threads(t, || {
            bench_opts(&format!("conv3_1_prepared_warm_t{t}"), Some(macs31), "MAC", opts, &mut || {
                std::hint::black_box(prepared.forward(&input31));
            })
        }));
    }

    section("activation pipeline at conv3_1 shape: fused im2col→quantize→pack vs unfused");
    let geo31 = Conv2dGeometry {
        in_channels: cin31,
        in_h: sp31,
        in_w: sp31,
        kernel_h: 3,
        kernel_w: 3,
        stride: 1,
        padding: 1,
    };
    let (k31, n31) = (geo31.k(), geo31.n());
    let lane31 = select_lane(cfg.w_format().frac_bits(), cfg.i_format().frac_bits(), k31);
    let elems31 = (k31 * n31) as f64;
    // unfused (pre-tiling data path): full K×N im2col buffer → K×N i32
    // quantize → pack into panels
    let mut col31 = vec![0f32; k31 * n31];
    let mut iq31 = BfpMatrix::empty();
    let mut acts_unfused = ActPanels::new();
    results.push(bench_opts("conv3_1_pipeline_unfused", Some(elems31), "elem", opts, &mut || {
        im2col(&input31.data, &geo31, &mut col31);
        iq31.requantize(&col31, k31, n31, cfg.i_format(), cfg.scheme.i_axis());
        acts_unfused.pack_matrix(&iq31, lane31);
        std::hint::black_box(&acts_unfused);
    }));
    // fused: NC-wide tiles quantized straight into the packed panels
    let mut acts_fused = ActPanels::new();
    let mut tile31 = Vec::new();
    results.push(bench_opts("conv3_1_pipeline_fused", Some(elems31), "elem", opts, &mut || {
        acts_fused.pack_im2col(&input31.data, &geo31, cfg.i_format(), cfg.scheme.i_axis(), lane31, &mut tile31);
        std::hint::black_box(&acts_fused);
    }));

    section("prepared forward_batch (8 images, image-parallel)");
    let batch: Vec<Tensor> = (0..8)
        .map(|_| Tensor::from_vec(rng.normal_vec(cin31 * sp31 * sp31, 1.0), &[cin31, sp31, sp31]))
        .collect();
    results.push(bench_opts("conv3_1_prepared_batch8", Some(macs31 * 8.0), "MAC", opts, &mut || {
        std::hint::black_box(prepared.forward_batch(batch.clone()));
    }));

    let tag = if smoke { "hotpath-smoke" } else { "hotpath" };
    // cargo bench runs with cwd = the package root (rust/); default the
    // JSON next to the workspace root where the tracked copy lives.
    // Smoke runs get their own file so a CI-style invocation can never
    // clobber the tracked full-shape trajectory numbers.
    let default_name = if smoke { "BENCH_hotpath_smoke.json" } else { "BENCH_hotpath.json" };
    let path = match std::env::var("BENCH_JSON") {
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => match std::env::var("CARGO_MANIFEST_DIR") {
            Ok(d) => std::path::Path::new(&d).join("..").join(default_name),
            Err(_) => std::path::PathBuf::from(default_name),
        },
    };
    write_json(&path, tag, &results).expect("write bench json");
    println!("\nwrote {} ({} benches)", path.display(), results.len());

    // `--baseline[=<path>]` (or BENCH_BASELINE=<path>): diff this run
    // against a committed baseline JSON and exit non-zero on any >15%
    // throughput regression — only when the baseline actually carries
    // comparable results (the tracked file starts as an empty
    // placeholder until a cargo-equipped host populates it).
    let baseline_path = std::env::args()
        .find_map(|a| {
            if a == "--baseline" {
                Some(None)
            } else {
                a.strip_prefix("--baseline=").map(|p| Some(std::path::PathBuf::from(p)))
            }
        })
        .or_else(|| std::env::var("BENCH_BASELINE").ok().map(|p| Some(std::path::PathBuf::from(p))));
    if let Some(explicit) = baseline_path {
        let bpath = explicit.unwrap_or_else(|| match std::env::var("CARGO_MANIFEST_DIR") {
            Ok(d) => std::path::Path::new(&d).join("..").join("BENCH_hotpath.json"),
            Err(_) => std::path::PathBuf::from("BENCH_hotpath.json"),
        });
        match read_baseline(&bpath) {
            Ok(base) if base.tag != tag => {
                println!("baseline {} is tagged {:?}, this run is {:?} — shapes differ, skipping diff", bpath.display(), base.tag, tag);
            }
            Ok(base) if base.entries.is_empty() => {
                println!("baseline {} has no results (placeholder) — nothing to compare", bpath.display());
            }
            Ok(base) => {
                let deltas = diff_against_baseline(&results, &base);
                let regressions = report_baseline_diff(&deltas);
                if regressions > 0 {
                    eprintln!("{regressions} bench(es) regressed >15% vs {}", bpath.display());
                    std::process::exit(1);
                }
            }
            Err(e) => println!("no baseline at {}: {e}", bpath.display()),
        }
    }
}
