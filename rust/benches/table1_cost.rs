//! Bench for Table 1: the storage cost model itself plus the *runtime*
//! cost the table abstracts — block-formatting throughput per scheme on
//! the real VGG-16 conv1_1 geometry (M=64, K=9, N=50176).
//!
//! Paper shape expected: eq3/eq5 pay thousands more block-format scans
//! (NBE column); eq2/eq4 amortise. Quantization throughput per element is
//! near-identical, so total cost tracks NBE.

use bfp_cnn::bfp::{BfpFormat, BfpMatrix, PartitionScheme};
use bfp_cnn::data::Rng;
use bfp_cnn::harness::benchkit::{bench, section};
use bfp_cnn::harness::table1;

fn main() {
    section("Table 1 — analytic cost model (all VGG-16 layers, 4 schemes)");
    bench("cost_model_all_layers", Some(13.0 * 4.0), "layer-scheme", || {
        for (_, m, k, n) in table1::vgg16_geometries() {
            for s in
                [PartitionScheme::Eq2, PartitionScheme::Eq3, PartitionScheme::Eq4, PartitionScheme::Eq5]
            {
                std::hint::black_box(s.cost(m, k, n, 8, 8, 8));
            }
        }
    });

    section("Table 1 — block formatting throughput, conv1_1 geometry");
    let (m, k, n) = (64usize, 9usize, 50176usize);
    let mut rng = Rng::new(1);
    let w = rng.laplacian_vec(m * k, 0.05);
    let i = rng.normal_vec(k * n, 40.0);
    let fmt = BfpFormat::new(8);
    for scheme in
        [PartitionScheme::Eq2, PartitionScheme::Eq3, PartitionScheme::Eq4, PartitionScheme::Eq5]
    {
        let elems = (m * k + k * n) as f64;
        bench(&format!("block_format_{scheme:?}"), Some(elems), "elem", || {
            std::hint::black_box(BfpMatrix::quantize(&w, m, k, fmt, scheme.w_axis()));
            std::hint::black_box(BfpMatrix::quantize(&i, k, n, fmt, scheme.i_axis()));
        });
    }

    section("Table 1 — rendered tables");
    for t in table1::run(8, 8) {
        t.print();
    }
}
