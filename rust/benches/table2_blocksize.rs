//! Bench for Table 2: eq. (2) vs eq. (4) block size on VGG-16 — both the
//! accuracy outcome (drop vs FP32) and the runtime cost of each scheme's
//! full BFP forward pass.

use bfp_cnn::coordinator::engine::{forward_batch_ref, ExecMode};
use bfp_cnn::bfp::PartitionScheme;
use bfp_cnn::harness::benchkit::{bench, section};
use bfp_cnn::harness::table2;
use bfp_cnn::models::ModelId;
use bfp_cnn::quant::BfpConfig;
use std::path::Path;

fn main() {
    let artifacts = Path::new("artifacts");
    let size = 32;

    section("Table 2 — accuracy (12 images, quick; `repro table2` for full)");
    let t = table2::run(size, 12, 1, artifacts);
    t.print();

    section("Table 2 — runtime of one VGG-16 BFP forward per scheme");
    let model = ModelId::Vgg16.build(size, 1, artifacts);
    let images = bfp_cnn::data::imagenet_like_batch(1, size, 3);
    for scheme in [PartitionScheme::Eq2, PartitionScheme::Eq4] {
        let cfg = BfpConfig::paper_default().with_scheme(scheme);
        bench(&format!("vgg16_bfp_forward_{scheme:?}"), Some(1.0), "img", || {
            std::hint::black_box(forward_batch_ref(&model, &images, ExecMode::Bfp(cfg)));
        });
    }
    bench("vgg16_fp32_forward", Some(1.0), "img", || {
        std::hint::black_box(forward_batch_ref(&model, &images, ExecMode::Fp32));
    });
}
